"""Reproduce the paper's evaluation tables/figures from the DiffLight
simulator and print them as formatted tables.

    PYTHONPATH=src python examples/photonic_report.py
"""
import numpy as np

from repro.configs.diffusion import PAPER_MODELS
from repro.core.photonic.arch import PAPER_OPTIMUM
from repro.core.photonic.baselines import derive_baselines
from repro.core.photonic.simulator import ablation, simulate
from repro.core.photonic.workload import unet_workload


def main():
    ws = {n: unet_workload(c, ctx_len=77 if c.context_dim else None)
          for n, c in PAPER_MODELS.items()}

    print('=== Fig. 8: normalized energy (lower is better) ===')
    cols = ['baseline', 'sw_opt', 'pipelined', 'dac_sharing', 'combined']
    print(f'{"model":16s} ' + ' '.join(f'{c:>12s}' for c in cols))
    ratios = []
    for n, w in ws.items():
        ab = ablation(w)
        base = ab['baseline'].energy_j
        print(f'{n:16s} ' + ' '.join(
            f'{ab[c].energy_j/base:12.3f}' for c in cols))
        ratios.append(base / ab['combined'].energy_j)
    print(f'--> average combined reduction: {np.mean(ratios):.2f}x '
          f'(paper: ~3x)\n')

    reps = {n: simulate(w, PAPER_OPTIMUM) for n, w in ws.items()}
    gops = float(np.mean([r.gops for r in reps.values()]))
    epb = float(np.mean([r.epb_pj for r in reps.values()]))
    print('=== DiffLight (combined config) per model ===')
    for n, r in reps.items():
        print(f'{n:16s} {r.gops:8.1f} GOPS  {r.epb_pj:8.4f} pJ/bit  '
              f'{r.latency_s*1e3:8.2f} ms/step')
    print()
    print('=== Figs. 9-10: vs state of the art (anchored to paper ratios,'
          ' see DESIGN.md) ===')
    print(f'{"baseline":24s} {"GOPS":>10s} {"EPB pJ/b":>10s} '
          f'{"GOPS x":>8s} {"EPB x":>8s}')
    for name, b in derive_baselines(gops, epb).items():
        print(f'{name:24s} {b.gops:10.2f} {b.epb_pj:10.4f} '
              f'{gops/b.gops:8.2f} {b.epb_pj/epb:8.2f}')


if __name__ == '__main__':
    main()
