"""Quickstart: the paper's core techniques in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention_decomp import decomp_flops
from repro.core.lse_softmax import lse_softmax, streaming_attention_ref
from repro.core.quantization import quantization_error, quantize_per_channel
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# --- C1: W8A8 quantized matmul (the MR-bank datapath) -----------------------
x = jax.random.normal(key, (64, 512))
w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
y_q = ops.w8a8_matmul(x, w)
rel = float(jnp.linalg.norm(y_q - x @ w) / jnp.linalg.norm(x @ w))
print(f'C1  W8A8 matmul     rel-err vs fp32 = {rel:.4f}  '
      f'(weight quant err  = {float(quantization_error(w)):.4f})')

# --- C2: streaming LSE softmax (the pipelined-softmax flash attention) ------
q = jax.random.normal(key, (1, 2, 128, 64))
k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 512, 64))
v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 512, 64))
out_stream = streaming_attention_ref(q, k, v, block=128)
s = jnp.einsum('bhsd,bhtd->bhst', q, k) * 64 ** -0.5
out_full = jnp.einsum('bhst,bhtd->bhsd', lse_softmax(s), v)
print(f'C2  streaming attn  max|diff| vs monolithic = '
      f'{float(jnp.abs(out_stream - out_full).max()):.2e}')

# --- C3: (Q W_K^T) X^T reordering — when does it win? ------------------------
std, reo = decomp_flops(S=1, T=32768, d=4096, d_k=128)
print(f'C3  Eq.6 reorder    decode regime: {std/reo:.1f}x fewer MACs')

# --- C4: zero-skipping transposed conv ---------------------------------------
from repro.core.sparse_dataflow import (conv_transpose_dense,
                                        conv_transpose_sparse,
                                        zero_mac_fraction)
xi = jax.random.normal(key, (1, 16, 16, 8))
ker = jax.random.normal(jax.random.PRNGKey(4), (4, 4, 8, 8))
d = conv_transpose_dense(xi, ker, 2)
sp = conv_transpose_sparse(xi, ker, 2)
print(f'C4  sparse convT    max|diff| = {float(jnp.abs(d-sp).max()):.2e}, '
      f'skips {zero_mac_fraction(4, 4, 2):.0%} of MACs')

# --- C7: the DiffLight simulator ---------------------------------------------
from repro.configs.diffusion import DDPM_CIFAR10
from repro.core.photonic.simulator import ablation
from repro.core.photonic.workload import unet_workload
ab = ablation(unet_workload(DDPM_CIFAR10))
base, comb = ab['baseline'], ab['combined']
print(f'C7  DiffLight sim   DDPM: {base.energy_j/comb.energy_j:.2f}x energy '
      f'reduction, {comb.gops:.0f} GOPS, {comb.epb_pj:.3f} pJ/bit')
