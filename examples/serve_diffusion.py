"""Continuous-batching diffusion serving demo (the paper's workload).

Serving quickstart
------------------
The engine multiplexes independent generation requests — each with its
own seed, DDIM step count, guidance AND precision — into fixed-shape
mixed-timestep UNet steps, so a request can be admitted the moment a
slot frees up instead of waiting for the whole batch.  Precision is
selected per request (``'fp32' | 'w8a8' | 'w8a8+noise'``): the engine
groups compatible precisions per tick and runs one pre-compiled step per
group, so mixing precisions never recompiles::

    from repro.serving import ContinuousBatchingEngine, GenerationRequest
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), unet_cfg)
    engine = ContinuousBatchingEngine(pipe, slots=8)
    engine.warmup(precisions=('fp32', 'w8a8'))   # one compile per policy
    engine.submit(GenerationRequest(request_id=0, seed=42, steps=50,
                                    precision='w8a8'))
    while engine.busy:
        for res in engine.tick():         # one UNet call per tick per policy
            print(res.request_id, res.latency_s, res.energy_j,
                  res.quality_psnr_db)    # PSNR vs the fp32 reference
    engine.metrics.snapshot().frontier    # accuracy-vs-EPB, per policy

Quantized requests are billed the simulated DiffLight energy (~94x lower
EPB than the GPU digital baseline an fp32 request is billed) and sampled
ones carry a PSNR/MSE quality probe against the fp32 reference — the
per-request points of the accuracy-vs-energy frontier.  This demo
replays a staggered arrival trace and compares against serving the same
requests as one naive batch-at-once call:

    PYTHONPATH=src python examples/serve_diffusion.py --requests 8 \
        --slots 4 --precision w8a8

Two scheduler levers compound on top of continuous batching (see the
README "Cache- and convergence-aware scheduling" section):
``--cache-interval k`` turns on DeepCache-phased slotting (one full UNet
pass every k ticks, shallow cached passes in between, all slots sharing
one refresh cadence) and ``--exit-tol`` drains a request early once its
x0 prediction stops moving between ticks.
"""
import argparse
import time

import jax
import numpy as np

from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import ContinuousBatchingEngine, GenerationRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--steps', type=int, default=6)
    ap.add_argument('--img', type=int, default=32)
    ap.add_argument('--rate', type=float, default=0.0,
                    help='arrival rate req/s (0 = auto from step time)')
    ap.add_argument('--precision', default='w8a8',
                    choices=['fp32', 'w8a8', 'w8a8+noise'],
                    help='per-request precision policy')
    ap.add_argument('--fp32', action='store_true',
                    help='deprecated alias for --precision fp32')
    ap.add_argument('--cache-interval', type=int, default=1,
                    help='DeepCache refresh cadence (1 = off): full UNet '
                         'pass every k ticks, shallow passes in between')
    ap.add_argument('--exit-tol', type=float, default=None,
                    help='early-exit tolerance on the relative x0 delta '
                         '(None/0 = off)')
    ap.add_argument('--exit-patience', type=int, default=2,
                    help='consecutive converged ticks before draining')
    ap.add_argument('--trace', default=None, metavar='PATH',
                    help='record per-request tracing and write a Chrome/'
                         'Perfetto trace_event timeline here')
    ap.add_argument('--log-json', default=None, metavar='PATH',
                    help='write the structured JSONL event log here')
    args = ap.parse_args()
    precision = 'fp32' if args.fp32 else args.precision

    cfg = UNetConfig('serve-demo', img_size=args.img, in_ch=3, base_ch=64,
                     ch_mults=(1, 2), n_res_blocks=1,
                     attn_resolutions=(args.img // 2,), n_heads=4,
                     timesteps=100)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, steps = args.requests, args.steps

    # --- naive batch-at-once baseline: wait for all N, one generate() ----
    from repro.core.precision import PrecisionPolicy
    pol = PrecisionPolicy.from_name(precision)
    gen = jax.jit(lambda k: pipe.generate(k, batch=N, steps=steps,
                                          policy=pol))
    print('[baseline] warmup (compile)...', flush=True)
    jax.block_until_ready(gen(jax.random.PRNGKey(1)))
    t0 = time.perf_counter()
    img = gen(jax.random.PRNGKey(2))
    jax.block_until_ready(img)
    t_batch = time.perf_counter() - t0
    assert np.all(np.isfinite(np.asarray(img)))

    # --- continuous batching over a staggered trace ----------------------
    # quality probe off for the throughput race; see --help of
    # repro.launch.serve for the probed frontier report
    tracer = None
    if args.trace or args.log_json:
        from repro.obs import Tracer
        tracer = Tracer()
    engine = ContinuousBatchingEngine(pipe, slots=args.slots,
                                      quality_probe=0,
                                      cache_interval=args.cache_interval,
                                      exit_tol=args.exit_tol,
                                      exit_patience=args.exit_patience,
                                      tracer=tracer)
    print('[engine] warmup (compile)...', flush=True)
    engine.warmup(precisions=(precision,))
    # arrivals spread over one baseline service window: batch-at-once can
    # only start when the last request lands; the engine starts at once
    rate = args.rate or N / max(t_batch, 1e-3)
    trace = [GenerationRequest(request_id=i, seed=100 + i, steps=steps,
                               arrival_time=i / rate, precision=precision)
             for i in range(N)]
    t0 = time.perf_counter()
    results = engine.replay(trace)
    makespan = time.perf_counter() - t0
    assert len(results) == N
    for r in results:
        assert np.all(np.isfinite(r.image))

    base_makespan = trace[-1].arrival_time + t_batch
    s = engine.metrics.summary()
    print(f'[baseline] batch-at-once: last arrival {trace[-1].arrival_time:.2f}s '
          f'+ {t_batch:.2f}s batch = {base_makespan:.2f}s '
          f'({N / base_makespan:.2f} img/s)')
    print(f'[engine]   continuous:   {makespan:.2f}s '
          f'({N / makespan:.2f} img/s, '
          f'p50={s["p50_latency_ms"]:.0f}ms p95={s["p95_latency_ms"]:.0f}ms)')
    print(f'[engine]   speedup vs batch-at-once: '
          f'{base_makespan / makespan:.2f}x')
    if args.cache_interval > 1 or s['steps_saved'] > 0:
        print(f'[sched]    cache_hit_rate={s["cache_hit_rate"]:.2f} '
              f'early_exits={int(s["early_exits"])} '
              f'steps_saved={int(s["steps_saved"])}')
    src = 'simulated DiffLight' if precision != 'fp32' \
        else 'GPU digital baseline'
    print(f'[energy]   {s["energy_per_request_mj"]:.2f} mJ/request '
          f'({s["total_energy_mj"]:.1f} mJ total, {src} '
          f'@ {results[0].epb_pj:.3f} pJ/bit, precision={precision})')
    if tracer is not None:
        from repro.obs import write_chrome_trace, write_jsonl
        if args.trace:
            n = write_chrome_trace(tracer, args.trace)
            print(f'[obs]      chrome trace: {n} events -> {args.trace}')
        if args.log_json:
            n = write_jsonl(tracer, args.log_json)
            print(f'[obs]      event log: {n} lines -> {args.log_json}')


if __name__ == '__main__':
    main()
