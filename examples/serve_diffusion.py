"""End-to-end driver (the paper's workload): serve batched image-generation
requests with a W8A8-quantized diffusion model, reporting throughput and the
simulated DiffLight energy for the same workload.

    PYTHONPATH=src python examples/serve_diffusion.py --batches 3 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.photonic.simulator import simulate
from repro.core.photonic.arch import PAPER_OPTIMUM
from repro.core.photonic.workload import unet_workload
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--batches', type=int, default=3)
    ap.add_argument('--steps', type=int, default=8)
    ap.add_argument('--img', type=int, default=32)
    ap.add_argument('--fp32', action='store_true',
                    help='disable W8A8 serving')
    args = ap.parse_args()

    cfg = UNetConfig('serve-demo', img_size=args.img, in_ch=3, base_ch=64,
                     ch_mults=(1, 2), n_res_blocks=1,
                     attn_resolutions=(args.img // 2,), n_heads=4,
                     timesteps=100)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg,
                                  quant=not args.fp32)
    gen = jax.jit(lambda k: pipe.generate(k, batch=args.batch,
                                          steps=args.steps))

    print(f'[serve] warmup (compile)...')
    jax.block_until_ready(gen(jax.random.PRNGKey(1)))

    t0 = time.perf_counter()
    for i in range(args.batches):
        img = gen(jax.random.PRNGKey(10 + i))
        jax.block_until_ready(img)
        assert np.all(np.isfinite(np.asarray(img)))
        print(f'[serve] batch {i}: {img.shape} '
              f'range [{float(img.min()):.2f}, {float(img.max()):.2f}]')
    dt = time.perf_counter() - t0
    n_img = args.batches * args.batch
    print(f'[serve] {n_img} images in {dt:.2f}s '
          f'({n_img/dt:.2f} img/s, W8A8={"off" if args.fp32 else "on"})')

    # what would DiffLight burn on this workload?
    w = unet_workload(cfg).scale(args.steps * n_img)
    rep = simulate(w, PAPER_OPTIMUM)
    print(f'[difflight] same workload on the photonic accelerator: '
          f'{rep.energy_j*1e3:.1f} mJ, {rep.latency_s*1e3:.1f} ms, '
          f'{rep.gops:.0f} GOPS, {rep.epb_pj:.3f} pJ/bit')


if __name__ == '__main__':
    main()
