"""Train a small LM from the assigned-architecture zoo on the synthetic
token stream, with checkpointing + resume — the full production loop at CPU
scale.

    PYTHONPATH=src python examples/train_lm.py --arch internlm2-1.8b \
        --steps 60
"""
import argparse
import tempfile

from repro.configs.registry import smoke_config
from repro.data.pipeline import TokenPipelineConfig
from repro.launch.mesh import make_mesh
from repro.launch.train import Trainer
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='internlm2-1.8b')
    ap.add_argument('--steps', type=int, default=60)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=64)
    ap.add_argument('--ckpt', default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    ckpt = args.ckpt or tempfile.mkdtemp(prefix='repro_ckpt_')
    mesh = make_mesh((1, 1), ('data', 'model'))
    tr = Trainer(cfg, mesh,
                 AdamWConfig(lr=3e-3, warmup_steps=5,
                             total_steps=args.steps),
                 ckpt_dir=ckpt)
    tr.maybe_restore()
    data = TokenPipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
    losses = tr.run(data, args.steps, ckpt_every=20, log_every=10)
    print(f'[example] {args.arch}: loss {losses[0]:.3f} -> {losses[-1]:.3f} '
          f'(ckpts in {ckpt})')
    assert losses[-1] < losses[0]


if __name__ == '__main__':
    main()
