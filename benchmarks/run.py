"""Benchmark harness — one section per paper table/figure.

Prints ``name,value,unit`` CSV rows (the BENCH_*.json schema: each row
is ``{name, value, unit}``; value is numeric wherever the quantity is,
unit is the physical/logical unit string):
  * Table I   — model parameter counts + W8A8 quality proxy
  * Fig. 8    — energy ablation (baseline vs S/W-opt vs pipelined vs
                DAC-sharing vs combined), per DM
  * Fig. 9    — GOPS vs CPU/GPU/DeepCache/FPGA1/FPGA2/PACE
  * Fig. 10   — EPB vs the same baselines
  * DSE       — paper config percentile in the budget-constrained sweep
  * kernels   — wall-time microbenches of the three Pallas kernel oracles
                (CPU) + sparse-vs-dense transposed conv
  * serving   — continuous-batching engine vs naive batch-at-once under a
                staggered arrival trace (requests/s + per-request energy)
  * quant_serving — the precision-policy fast path: the same trace served
                at fp32 vs w8a8 (requests/s, EPB, PSNR quality probe) plus
                a mixed-precision zero-recompile check
  * cache_serving — the cache- and convergence-aware scheduler: the same
                Poisson trace served by the full-step engine vs the
                DeepCache-phased + early-exit engine (requests/s speedup,
                PSNR vs the full-step fp32 reference, per-request energy
                with skip ticks billed at the shallow workload fraction)
  * coldstart — time-to-first-tick across REAL process restarts: a cold
                subprocess (empty persistent compilation cache) vs a warm
                one (same cache dir, second run) — the restart recompile
                storm vs the cache load
  * overload  — a 5x-overload Poisson trace against a bounded
                deadline-aware queue: shed rate by cause, p99 queue wait,
                peak queue depth (the survival proof)
  * sharded_serving — the slot-sharded engine at 1/2/4/8 simulated
                devices: device-parallel requests/s modeled from
                measured per-device tick times (the host simulation
                serializes devices, so wall clock is emitted separately
                as the audit trail), plus decode overlap on/off at 8
                devices and a zero-recompile check
  * obs_overhead — the observability tax: the same request batch served
                with tracing off (NULL_TRACER) vs on (a live Tracer
                recording every span); asserts the traced requests/s is
                within 5% of untraced (best-of-3 each, so scheduler
                noise does not fail the gate) and reports the per-run
                event volume

Rows persist to ``BENCH_PR10.json`` at the repo root (NaN/inf values
are sanitized to null — the file is strict JSON).  Older
``BENCH_PR*.json`` files used ``{name, us_per_call, derived}`` rows;
``load_bench`` reads both shapes.

Regression gate: by default a >10% drop of ``serving/engine_rps`` vs
the newest prior ``BENCH_PR*.json`` only WARNS on stderr.  With
``--check`` the run becomes a merge gate — it compares against the
newest *committed* bench file (including this PR's), exits nonzero on
regression, and does not persist rows.  ``BENCH_TOL`` (fraction,
default 0.10) loosens the gate for slower CI hardware.

Run everything (default) or name sections on argv:
    PYTHONPATH=src python benchmarks/run.py cache_serving
    PYTHONPATH=src python benchmarks/run.py serving --check   # CI gate
"""
import glob
import json
import os
import re
import subprocess
import sys
import tempfile
import time

import numpy as np


def _timeit(fn, iters=5):
    fn()                                   # compile / warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6


def bench_table1(emit):
    import jax
    from repro.configs.diffusion import PAPER_MODELS, PAPER_PARAM_COUNTS
    from repro.models.unet import init_unet
    for name, cfg in PAPER_MODELS.items():
        shapes = jax.eval_shape(lambda c=cfg: init_unet(
            jax.random.PRNGKey(0), c))
        n = sum(int(np.prod(s.shape)) for s in
                jax.tree_util.tree_leaves(shapes))
        emit(f'table1/{name}/params', round(n / 1e6, 2), 'Mparams')
        emit(f'table1/{name}/paper_params',
             round(PAPER_PARAM_COUNTS[name], 2), 'Mparams')


def _workloads():
    from repro.configs.diffusion import PAPER_MODELS
    from repro.core.photonic.workload import unet_workload
    return {n: unet_workload(c, ctx_len=77 if c.context_dim else None)
            for n, c in PAPER_MODELS.items()}


def bench_fig8(emit):
    from repro.core.photonic.simulator import ablation
    ratios = []
    for name, w in _workloads().items():
        ab = ablation(w)
        base = ab['baseline'].energy_j
        for k, r in ab.items():
            emit(f'fig8/{name}/{k}/norm_energy',
                 round(r.energy_j / base, 4), 'ratio')
        ratios.append(base / ab['combined'].energy_j)
    emit('fig8/avg_combined_reduction', round(float(np.mean(ratios)), 2),
         'x')


def bench_fig9_fig10(emit):
    from repro.core.photonic.arch import PAPER_OPTIMUM
    from repro.core.photonic.baselines import derive_baselines
    from repro.core.photonic.simulator import simulate
    ws = _workloads()
    reps = {n: simulate(w, PAPER_OPTIMUM) for n, w in ws.items()}
    for n, r in reps.items():
        emit(f'fig9/{n}/difflight_throughput', round(r.gops, 1), 'GOPS')
        emit(f'fig10/{n}/difflight_epb', round(r.epb_pj, 4), 'pJ/bit')
    gops = float(np.mean([r.gops for r in reps.values()]))
    epb = float(np.mean([r.epb_pj for r in reps.values()]))
    for name, b in derive_baselines(gops, epb).items():
        key = name.split(' ')[0].lower().replace('_', '')
        emit(f'fig9/baseline/{key}_throughput', round(b.gops, 2), 'GOPS')
        emit(f'fig10/baseline/{key}_epb', round(b.epb_pj, 4), 'pJ/bit')
        emit(f'fig9/improvement/{key}', round(gops / b.gops, 2), 'x')
        emit(f'fig10/improvement/{key}', round(b.epb_pj / epb, 2), 'x')


def bench_deepcache(emit):
    """Derived (not anchored) DeepCache comparison point: our DeepCache
    implementation's MAC factor -> throughput/energy point on the same
    simulator, vs the paper's anchored 192x GOPS / 376x EPB ratios."""
    from repro.configs.diffusion import PAPER_MODELS
    from repro.core.photonic.arch import PAPER_OPTIMUM
    from repro.core.photonic.simulator import simulate
    from repro.core.photonic.workload import unet_workload
    from repro.diffusion.deepcache import deepcache_workload_factor
    for name, cfg in PAPER_MODELS.items():
        f = deepcache_workload_factor(cfg, interval=5)
        emit(f'deepcache/{name}/mac_factor', round(f, 3), 'ratio')
    # DiffLight running the DeepCache-reduced workload: compounding check
    w = unet_workload(PAPER_MODELS['ddpm_cifar10'])
    f = deepcache_workload_factor(PAPER_MODELS['ddpm_cifar10'], 5)
    r_full = simulate(w, PAPER_OPTIMUM)
    r_dc = simulate(w.scale(f), PAPER_OPTIMUM)
    emit('deepcache/difflight_compound_energy',
         round(r_full.energy_j / r_dc.energy_j, 2), 'x')


def bench_dse(emit):
    from repro.configs.diffusion import PAPER_MODELS
    from repro.core.photonic.arch import PAPER_OPTIMUM, dse_space
    from repro.core.photonic.simulator import dse_score
    from repro.core.photonic.workload import unet_workload
    w = unet_workload(PAPER_MODELS['sd_v1_4'], ctx_len=77)

    def mr_count(c):
        return (c.Y * 2 * c.K * c.N + c.H * (4 * c.M * c.L + 3 * c.M * c.N)
                + 2 * c.M * c.L)
    budget = 1.1 * mr_count(PAPER_OPTIMUM)
    t0 = time.perf_counter()
    scored = [(dse_score(w, c), c) for c in dse_space()
              if mr_count(c) <= budget]
    dt = (time.perf_counter() - t0) * 1e6
    scored.sort(key=lambda x: -x[0])
    mine = dse_score(w, PAPER_OPTIMUM)
    pct = float(np.searchsorted(-np.asarray([s for s, _ in scored]),
                                -mine)) / len(scored)
    best = scored[0][1]
    emit('dse/n_configs', len(scored), 'configs')
    emit('dse/sweep_time', round(dt, 1), 'us')
    emit('dse/paper_config_percentile', round(pct, 3), 'fraction')
    emit('dse/our_optimum',
         f'[{best.Y} {best.N} {best.K} {best.H} {best.L} {best.M}]',
         'config')


def bench_kernels(emit):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    f32 = jax.jit(lambda: x @ w)
    q = jax.jit(lambda: ops.w8a8_matmul(x, w, mode='xla'))
    emit('kernels/matmul_f32', round(_timeit(f32), 1), 'us')
    emit('kernels/w8a8_matmul_xla', round(_timeit(q), 1), 'us')
    qq = jnp.asarray(rng.normal(size=(2, 4, 128, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    fa = jax.jit(lambda: ops.flash_attention(qq, kk, kk, mode='xla'))
    emit('kernels/flash_attention_xla', round(_timeit(fa), 1), 'us')
    img = jnp.asarray(rng.normal(size=(2, 32, 32, 64)), jnp.float32)
    sc = jnp.ones((64,))
    gs = jax.jit(lambda: ops.fused_gn_swish(img, sc, sc, mode='xla'))
    emit('kernels/fused_gn_swish_xla', round(_timeit(gs), 1), 'us')
    # C4: sparse vs dense transposed conv wall time (CPU)
    from repro.core import sparse_dataflow as SD
    xc = jnp.asarray(rng.normal(size=(2, 32, 32, 64)), jnp.float32)
    ker = jnp.asarray(rng.normal(size=(4, 4, 64, 64)), jnp.float32)
    dense = jax.jit(lambda: SD.conv_transpose_dense(xc, ker, 2))
    sparse = jax.jit(lambda: SD.conv_transpose_sparse(xc, ker, 2))
    td, ts = _timeit(dense), _timeit(sparse)
    emit('kernels/convt_dense', round(td, 1), 'us')
    emit('kernels/convt_sparse', round(ts, 1), 'us')
    emit('kernels/convt_sparse_speedup', round(td / max(ts, 1e-9), 2), 'x')


def bench_serving(emit):
    """Continuous batching vs batch-at-once under staggered arrivals with
    heterogeneous step counts (the serving reality: users ask for
    different quality/step budgets).

    Batch-at-once can only launch once the LAST request has arrived, and
    its fixed-shape sampler must run the WHOLE batch for max(steps); the
    engine starts at the first arrival, gives each slot its own step
    trajectory, and refills a slot the moment a short request drains."""
    import jax
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.serving import ContinuousBatchingEngine, GenerationRequest
    cfg = UNetConfig('bench-serve', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=50)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, slots = 8, 4
    step_counts = [3 + (3 * i) % 8 for i in range(N)]        # 3..10, mixed
    max_steps = max(step_counts)
    gen = jax.jit(lambda k: pipe.generate(k, batch=N, steps=max_steps))
    jax.block_until_ready(gen(jax.random.PRNGKey(1)))       # compile
    t0 = time.perf_counter()
    jax.block_until_ready(gen(jax.random.PRNGKey(2)))
    t_batch = time.perf_counter() - t0

    engine = ContinuousBatchingEngine(pipe, slots=slots)
    engine.warmup()
    # requests staggered across one batch-service window
    trace = [GenerationRequest(request_id=i, seed=100 + i,
                               steps=step_counts[i],
                               arrival_time=i * t_batch / N)
             for i in range(N)]
    warm = engine.compile_stats()
    t0 = time.perf_counter()
    results = engine.replay(trace)
    makespan = time.perf_counter() - t0
    assert len(results) == N
    assert engine.compile_stats() == warm, 'engine recompiled mid-serve'

    base_makespan = trace[-1].arrival_time + t_batch
    base_rps = N / base_makespan
    eng_rps = N / makespan
    s = engine.metrics.summary()
    emit('serving/batch_at_once_rps', round(base_rps, 3), 'req/s')
    emit('serving/engine_rps', round(eng_rps, 3), 'req/s')
    emit('serving/speedup', round(eng_rps / base_rps, 2), 'x')
    emit('serving/p50_latency', round(s['p50_latency_ms'], 1), 'ms')
    emit('serving/p95_latency', round(s['p95_latency_ms'], 1), 'ms')
    emit('serving/energy_per_request',
         round(s['energy_per_request_mj'], 3), 'mJ')


def bench_quant_serving(emit):
    """fp32 vs w8a8 serving on the SAME trace: the precision-policy fast
    path's headline numbers — requests/s, per-request energy/EPB (fp32 is
    billed the GPU digital baseline, w8a8 the DiffLight simulation), the
    PSNR quality probe, and a mixed-precision zero-recompile check."""
    import jax
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.serving import ContinuousBatchingEngine, GenerationRequest
    cfg = UNetConfig('bench-qserve', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=50)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, slots = 6, 3
    step_counts = [3 + (2 * i) % 5 for i in range(N)]        # 3..7, mixed

    def serve(precision, n=N, quality_probe=0):
        # probe off while timing: the eager fp32 reference is measurement
        # apparatus, not served work
        engine = ContinuousBatchingEngine(pipe, slots=slots,
                                          quality_probe=quality_probe)
        engine.warmup(precisions=(precision,))
        for i in range(n):
            engine.submit(GenerationRequest(
                request_id=i, seed=100 + i, steps=step_counts[i % N],
                precision=precision), now=0.0)
        warm = engine.compile_stats()
        t0 = time.perf_counter()
        results = engine.run_until_idle(now=0.0, tick_dt=0.01)
        makespan = time.perf_counter() - t0
        assert len(results) == n
        assert engine.compile_stats() == warm, 'recompiled mid-serve'
        f = engine.metrics.frontier()[precision]
        return n / makespan, f

    fp32_rps, fp32_f = serve('fp32')
    w8a8_rps, w8a8_f = serve('w8a8')
    _, w8a8_q = serve('w8a8', n=2, quality_probe=1)    # quality pass
    emit('quant_serving/fp32_rps', round(fp32_rps, 3), 'req/s')
    emit('quant_serving/w8a8_rps', round(w8a8_rps, 3), 'req/s')
    emit('quant_serving/fp32_epb', round(fp32_f['mean_epb_pj'], 4),
         'pJ/bit')
    emit('quant_serving/w8a8_epb', round(w8a8_f['mean_epb_pj'], 4),
         'pJ/bit')
    emit('quant_serving/fp32_energy_per_req',
         round(fp32_f['mean_energy_j'] * 1e3, 4), 'mJ')
    emit('quant_serving/w8a8_energy_per_req',
         round(w8a8_f['mean_energy_j'] * 1e3, 4), 'mJ')
    emit('quant_serving/epb_improvement',
         round(fp32_f['mean_epb_pj'] / w8a8_f['mean_epb_pj'], 2), 'x')
    emit('quant_serving/w8a8_psnr_vs_fp32',
         round(w8a8_q['mean_psnr_db'], 2), 'dB')
    emit('quant_serving/w8a8_mse_vs_fp32',
         float(f"{w8a8_q['mean_mse']:.3e}"), 'mse')

    # mixed-precision tick: every policy in one engine, zero recompiles
    engine = ContinuousBatchingEngine(pipe, slots=slots, quality_probe=0)
    engine.warmup(precisions=('fp32', 'w8a8', 'w8a8+noise'))
    warm = engine.compile_stats()
    mix = ['fp32', 'w8a8', 'w8a8+noise']
    for i in range(N):
        engine.submit(GenerationRequest(
            request_id=100 + i, seed=200 + i, steps=step_counts[i],
            precision=mix[i % 3]), now=0.0)
    results = engine.run_until_idle(now=0.0, tick_dt=0.01)
    assert len(results) == N
    ok = engine.compile_stats() == warm
    emit('quant_serving/mixed_zero_recompiles', int(ok), 'bool')


def bench_cache_serving(emit):
    """The cache- and convergence-aware scheduler's headline numbers:
    the SAME Poisson trace served by (a) the PR6-style full-step engine
    and (b) the DeepCache-phased engine with speculative early exit.

    Reports the requests/s speedup, PSNR of the scheduled outputs vs the
    full-step fp32 reference (quality probe), the per-request energy with
    skip ticks billed at the shallow workload fraction of a full UNet
    pass, and a zero-recompile check on the cached engine (the refresh /
    skip pair is pre-compiled at warmup)."""
    import jax
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.serving import ContinuousBatchingEngine, GenerationRequest
    cfg = UNetConfig('bench-cserve', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=50)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, slots, steps = 8, 4, 12
    interval, exit_tol, patience = 3, 0.005, 2
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(0.02, N))      # same Poisson trace

    def trace():
        return [GenerationRequest(request_id=i, seed=100 + i, steps=steps,
                                  arrival_time=float(arrivals[i]))
                for i in range(N)]

    def serve(n=N, quality_probe=0, **knobs):
        engine = ContinuousBatchingEngine(pipe, slots=slots,
                                          quality_probe=quality_probe,
                                          **knobs)
        engine.warmup()
        for req in trace()[:n]:
            engine.submit(req, now=0.0)
        warm = engine.compile_stats()
        t0 = time.perf_counter()
        results = engine.run_until_idle(now=0.0, tick_dt=0.01)
        makespan = time.perf_counter() - t0
        assert len(results) == n
        assert engine.compile_stats() == warm, 'recompiled mid-serve'
        return n / makespan, engine.metrics

    full_rps, full_m = serve()                              # PR6 baseline
    cached_rps, cached_m = serve(cache_interval=interval, exit_tol=exit_tol,
                                 exit_patience=patience)
    # quality pass: probe the scheduled outputs against the eager
    # full-step fp32 reference (probe excluded from the timed runs)
    _, qual_m = serve(n=3, quality_probe=1, cache_interval=interval,
                      exit_tol=exit_tol, exit_patience=patience)

    s = cached_m.summary()
    fq = qual_m.frontier()['fp32']
    f_full = full_m.frontier()['fp32']
    f_cached = cached_m.frontier()['fp32']
    emit('cache_serving/full_step_rps', round(full_rps, 3), 'req/s')
    emit('cache_serving/cached_rps', round(cached_rps, 3), 'req/s')
    emit('cache_serving/speedup', round(cached_rps / full_rps, 2), 'x')
    emit('cache_serving/cache_interval', interval, 'ticks')
    emit('cache_serving/cache_hit_rate', round(s['cache_hit_rate'], 3),
         'fraction')
    emit('cache_serving/early_exits', int(s['early_exits']), 'requests')
    emit('cache_serving/steps_saved', int(s['steps_saved']), 'steps')
    emit('cache_serving/mean_steps_executed',
         round(f_cached['mean_steps_executed'], 2), 'steps')
    emit('cache_serving/full_energy_per_req',
         round(f_full['mean_energy_j'] * 1e3, 4), 'mJ')
    emit('cache_serving/cached_energy_per_req',
         round(f_cached['mean_energy_j'] * 1e3, 4), 'mJ')
    emit('cache_serving/energy_reduction',
         round(f_full['mean_energy_j'] / f_cached['mean_energy_j'], 2),
         'x')
    emit('cache_serving/psnr_vs_full_fp32', round(fq['mean_psnr_db'], 2),
         'dB')
    emit('cache_serving/zero_recompiles', 1, 'bool')


# child of bench_coldstart: one full serve cold start in a FRESH process
# (pipeline init + warmup + first tick), persisting compilations to the
# cache dir in argv[1] and reporting the timings as JSON on stdout.
_COLDSTART_CHILD = r"""
import json, os, sys, time
os.environ['JAX_PLATFORMS'] = 'cpu'
t_proc = time.perf_counter()
import jax
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           cache_entries)
cfg = UNetConfig('bench-coldstart', img_size=16, in_ch=3, base_ch=32,
                 ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                 n_heads=4, timesteps=50)
pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
warmup_s = engine.warmup(cache_dir=sys.argv[1])
engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
engine.run_until_idle(now=0.0)
print(json.dumps({'warmup_s': warmup_s,
                  'first_tick_s': engine.metrics.first_tick_s,
                  'proc_s': time.perf_counter() - t_proc,
                  'cache_entries': cache_entries(sys.argv[1])}))
"""


def _coldstart_child(cache_dir):
    env = dict(os.environ)
    env['PYTHONPATH'] = os.path.join(ROOT, 'src') + (
        os.pathsep + env['PYTHONPATH'] if env.get('PYTHONPATH') else '')
    env['JAX_PLATFORMS'] = 'cpu'
    out = subprocess.run([sys.executable, '-c', _COLDSTART_CHILD, cache_dir],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f'coldstart child failed:\n{out.stderr[-2000:]}')
    return json.loads(out.stdout.strip().splitlines()[-1])


def bench_coldstart(emit):
    """Cold vs warm restart, measured across REAL process boundaries:
    the same serve bring-up (pipeline init, engine warmup, first tick)
    runs twice in fresh subprocesses sharing one persistent compilation
    cache directory.  Run 1 (cold, empty dir) pays the recompile storm
    and persists every executable; run 2 (warm) loads them from disk —
    the time-to-first-tick gap is what the persistent cache buys a
    restarted server."""
    with tempfile.TemporaryDirectory(prefix='repro-xla-cache-') as d:
        cold = _coldstart_child(d)
        assert cold['cache_entries'] > 0, 'cold run persisted nothing'
        warm = _coldstart_child(d)
    emit('coldstart/cold_warmup', round(cold['warmup_s'], 3), 's')
    emit('coldstart/warm_warmup', round(warm['warmup_s'], 3), 's')
    emit('coldstart/cold_first_tick', round(cold['first_tick_s'], 3), 's')
    emit('coldstart/warm_first_tick', round(warm['first_tick_s'], 3), 's')
    emit('coldstart/warmup_speedup',
         round(cold['warmup_s'] / max(warm['warmup_s'], 1e-9), 2), 'x')
    emit('coldstart/first_tick_speedup',
         round(cold['first_tick_s'] / max(warm['first_tick_s'], 1e-9), 2),
         'x')
    emit('coldstart/cache_entries', int(cold['cache_entries']), 'files')


# child of bench_sharded_serving: one process with 8 simulated host
# devices sweeps slot-sharded engines over 1/2/4/8-device meshes on a
# fixed request batch, counting scheduler ticks and wall time, and
# anchors the device-parallel model with the 1-device engine's measured
# tick time.  Reports JSON on stdout.
_SHARDED_CHILD = r"""
import json, os, sys, time
os.environ['JAX_PLATFORMS'] = 'cpu'
import jax
from repro.diffusion.pipeline import DiffusionPipeline
from repro.launch.mesh import serving_mesh
from repro.models.unet import UNetConfig
from repro.serving import ContinuousBatchingEngine, GenerationRequest

cfg = UNetConfig('bench-sharded', img_size=16, in_ch=3, base_ch=32,
                 ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                 n_heads=4, timesteps=50)
pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
N, SPD, STEPS = 32, 2, 6

def run(n_dev, overlap=None, measure=False):
    e = ContinuousBatchingEngine(pipe, slots_per_device=SPD,
                                 mesh=serving_mesh(n_dev), quality_probe=0,
                                 overlap_decode=overlap)
    e.warmup()
    stats0 = e.compile_stats()
    for i in range(N):
        e.submit(GenerationRequest(request_id=i, seed=700 + i, steps=STEPS,
                                   exit_tol=0.0), now=0.0)
    out, ticks = [], 0
    t0 = time.perf_counter()
    while e.busy:
        out.extend(e.tick(now=0.0))
        ticks += 1
    wall = time.perf_counter() - t0
    assert len(out) == N, f'{n_dev}dev: {len(out)}/{N} completed'
    assert e.compile_stats() == stats0, f'{n_dev}dev recompiled mid-serve'
    r = {'slots': e.slots, 'ticks': ticks, 'wall_s': wall,
         'overlapped': e.metrics.overlapped_decodes}
    if measure:
        r['tick_s'] = e.measure_tick_s(steps=16)
    return r

report = {'n_devices': jax.device_count(), 'n_requests': N, 'runs': {}}
for n in (1, 2, 4, 8):
    report['runs'][str(n)] = run(n, measure=(n == 1))
report['overlap_on'] = run(8, overlap=True)
report['overlap_off'] = run(8, overlap=False)
print('REPORT ' + json.dumps(report))
"""


def _sharded_child():
    env = dict(os.environ)
    env['PYTHONPATH'] = os.path.join(ROOT, 'src') + (
        os.pathsep + env['PYTHONPATH'] if env.get('PYTHONPATH') else '')
    env['JAX_PLATFORMS'] = 'cpu'
    env['XLA_FLAGS'] = (env.get('XLA_FLAGS', '')
                        + ' --xla_force_host_platform_device_count=8').strip()
    out = subprocess.run([sys.executable, '-c', _SHARDED_CHILD],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f'sharded child failed:\n{out.stderr[-2000:]}')
    lines = [l for l in out.stdout.splitlines() if l.startswith('REPORT ')]
    if not lines:
        raise RuntimeError(f'sharded child printed no report:\n{out.stdout}')
    return json.loads(lines[-1][len('REPORT '):])


def bench_sharded_serving(emit):
    """Slot-sharded serving throughput at 1/2/4/8 devices, plus decode
    overlap on/off at 8 devices.

    The mesh is simulated on the host
    (``--xla_force_host_platform_device_count=8``), which SERIALIZES the
    per-device programs on one CPU — simulation wall clock cannot show
    device parallelism.  Slot sharding keeps the per-device program
    identical at every mesh size (same per-device batch, same kernels),
    so one tick of an N-device mesh takes one 1-device tick of wall
    time on real hardware; device-parallel throughput is therefore
    modeled as ``requests / (ticks * measured 1-device tick time)`` —
    the same measured-tick model the overload section uses for capacity.
    The serialized simulation wall rates are also emitted so the model
    is auditable against what actually ran."""
    rep = _sharded_child()
    assert rep['n_devices'] == 8, 'host device simulation failed'
    n_req = rep['n_requests']
    tick1 = rep['runs']['1']['tick_s']
    modeled = {}
    for n in (1, 2, 4, 8):
        r = rep['runs'][str(n)]
        modeled[n] = n_req / (r['ticks'] * tick1)
        emit(f'sharded_serving/rps_{n}dev', round(modeled[n], 2), 'req/s')
    speedup = modeled[8] / modeled[1]
    assert speedup > 1.5, f'8-device speedup {speedup:.2f}x <= 1.5x'
    emit('sharded_serving/speedup_8v1', round(speedup, 2), 'x')
    emit('sharded_serving/slots_8dev', rep['runs']['8']['slots'], 'slots')
    emit('sharded_serving/ticks_1dev', rep['runs']['1']['ticks'], 'ticks')
    emit('sharded_serving/ticks_8dev', rep['runs']['8']['ticks'], 'ticks')
    emit('sharded_serving/sim_wall_rps_1dev',
         round(n_req / rep['runs']['1']['wall_s'], 2), 'req/s')
    emit('sharded_serving/sim_wall_rps_8dev',
         round(n_req / rep['runs']['8']['wall_s'], 2), 'req/s')
    on, off = rep['overlap_on'], rep['overlap_off']
    assert on['overlapped'] > 0, 'decode overlap never engaged'
    emit('sharded_serving/overlap_on_rps',
         round(n_req / on['wall_s'], 2), 'req/s')
    emit('sharded_serving/overlap_off_rps',
         round(n_req / off['wall_s'], 2), 'req/s')
    emit('sharded_serving/overlapped_decodes', on['overlapped'], 'decodes')
    emit('sharded_serving/zero_recompiles', 1, 'bool')


def bench_overload(emit):
    """Survival under 5x overload: a Poisson trace offering five times
    the engine's measured service capacity hits a bounded deadline-aware
    queue.  The engine must complete what fits, shed the rest (tallied
    by cause), keep the queue at or under its bound, and never let a
    deadline-dead request occupy a slot."""
    import jax
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                               GenerationRequest, overload_factor)
    cfg = UNetConfig('bench-overload', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=50)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, slots, steps, depth, factor = 40, 4, 6, 8, 5.0
    engine = ContinuousBatchingEngine(
        pipe, slots=slots, quality_probe=0,
        queue=AdmissionQueue(max_depth=depth, shed_policy='deadline-aware'))
    engine.warmup()
    tick_s = engine.measure_tick_s(steps=steps)
    capacity_rps = slots / (steps * tick_s)
    rate = factor * capacity_rps
    slo_ms = 3.0 * steps * tick_s * 1e3
    rng = np.random.default_rng(11)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, N))
    trace = [GenerationRequest(request_id=i, seed=500 + i, steps=steps,
                               arrival_time=float(arrivals[i]),
                               slo_ms=slo_ms) for i in range(N)]
    results = engine.replay(trace)
    s = engine.metrics.summary()
    by = engine.metrics.shed_by_reason
    assert len(results) + int(s['shed']) == N, 'requests lost'
    assert s['max_queue_depth'] <= depth, 'queue bound broken'
    assert s['shed'] > 0, '5x overload must shed'
    emit('overload/offered_x',
         round(overload_factor(rate, tick_s, steps, slots), 2), 'x')
    emit('overload/capacity', round(capacity_rps, 2), 'req/s')
    emit('overload/offered', round(rate, 2), 'req/s')
    emit('overload/completed', len(results), 'requests')
    emit('overload/shed', int(s['shed']), 'requests')
    emit('overload/shed_rate', round(s['shed'] / N, 3), 'fraction')
    emit('overload/shed_evicted', by.get('deadline_evict', 0), 'requests')
    emit('overload/shed_expired', by.get('expired', 0), 'requests')
    emit('overload/shed_queue_full', by.get('queue_full', 0), 'requests')
    emit('overload/max_queue_depth', int(s['max_queue_depth']), 'requests')
    emit('overload/queue_bound', depth, 'requests')
    emit('overload/p50_queue_wait', round(s['p50_queue_wait_ms'], 1), 'ms')
    emit('overload/p99_queue_wait', round(s['p99_queue_wait_ms'], 1), 'ms')
    emit('overload/slo', round(slo_ms, 1), 'ms')


def bench_obs_overhead(emit):
    """The observability tax: the SAME request batch served with tracing
    disabled (the zero-cost NULL_TRACER default) and enabled (a live
    ``Tracer`` recording submit/slot-assign/step/tick/decode/request
    events).  Hot paths guard on ``tracer.enabled``, so the traced run
    must stay within 5% of the untraced requests/s — asserted on the
    best-of-3 makespans per mode so scheduler noise cannot fail the
    gate.  Also reports the event volume one run records."""
    import jax
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.obs import Tracer
    from repro.serving import ContinuousBatchingEngine, GenerationRequest
    cfg = UNetConfig('bench-obs', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=50)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    N, slots, steps, reps = 10, 4, 6, 3
    engine = ContinuousBatchingEngine(pipe, slots=slots, quality_probe=0)
    engine.warmup()

    def serve(tracer):
        from repro.obs import NULL_TRACER
        saved = engine.tracer
        engine.tracer = tracer if tracer is not None else NULL_TRACER
        for i in range(N):
            engine.submit(GenerationRequest(
                request_id=i, seed=300 + i, steps=steps, exit_tol=0.0),
                now=0.0)
        t0 = time.perf_counter()
        results = engine.run_until_idle(now=0.0, tick_dt=0.01)
        makespan = time.perf_counter() - t0
        engine.tracer = saved
        assert len(results) == N
        return makespan

    # interleave modes so drift (thermal, background load) hits both
    plain_times, traced_times, tracers = [], [], []
    for _ in range(reps):
        plain_times.append(serve(None))
        tracers.append(Tracer())
        traced_times.append(serve(tracers[-1]))
    plain, traced = min(plain_times), min(traced_times)
    events = max(len(tr) for tr in tracers)
    plain_rps, traced_rps = N / plain, N / traced
    overhead = max(0.0, 1.0 - traced_rps / plain_rps)
    assert overhead < 0.05, \
        f'tracing overhead {overhead:.1%} >= 5% ' \
        f'({plain_rps:.2f} -> {traced_rps:.2f} req/s)'
    emit('obs_overhead/untraced_rps', round(plain_rps, 3), 'req/s')
    emit('obs_overhead/traced_rps', round(traced_rps, 3), 'req/s')
    emit('obs_overhead/overhead', round(overhead, 4), 'fraction')
    emit('obs_overhead/events_per_run', events, 'events')
    emit('obs_overhead/events_per_request', round(events / N, 1), 'events')


SECTIONS = {
    'table1': bench_table1,
    'fig8': bench_fig8,
    'fig9_fig10': bench_fig9_fig10,
    'deepcache': bench_deepcache,
    'dse': bench_dse,
    'kernels': bench_kernels,
    'serving': bench_serving,
    'quant_serving': bench_quant_serving,
    'cache_serving': bench_cache_serving,
    'coldstart': bench_coldstart,
    'overload': bench_overload,
    'sharded_serving': bench_sharded_serving,
    'obs_overhead': bench_obs_overhead,
}

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), '..')
BENCH_JSON = os.path.join(ROOT, 'BENCH_PR10.json')


def load_bench(path):
    """Read a BENCH_*.json into {name: value}, accepting both row shapes:
    the current ``{name, value, unit}`` and the pre-PR7
    ``{name, us_per_call, derived}`` (where the quantity of record lived
    in the ``derived`` string)."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get('rows', []):
        if 'value' in row:
            out[row['name']] = row['value']
            continue
        val = row.get('derived', '')
        try:
            val = float(val)
        except (TypeError, ValueError):
            pass
        out[row['name']] = val
    return out


def _newest_prior_bench(include_current=False):
    """Newest BENCH_PR<k>.json at the repo root (highest k wins — the
    stacked-PR sequence is the clock).  Persist runs exclude the file
    this run writes (it may hold a half-written previous attempt); the
    ``--check`` gate includes it, because once committed it IS the
    newest agreed-on baseline."""
    best, best_k = None, -1
    for path in glob.glob(os.path.join(ROOT, 'BENCH_PR*.json')):
        if (not include_current
                and os.path.abspath(path) == os.path.abspath(BENCH_JSON)):
            continue
        m = re.search(r'BENCH_PR(\d+)\.json$', path)
        if m and int(m.group(1)) > best_k:
            best, best_k = path, int(m.group(1))
    return best


def check_regression(rows, guard='serving/engine_rps', tol=None,
                     fail=False):
    """Compare this run's ``guard`` metric against the newest committed
    BENCH_PR*.json.  Default mode warns on stderr and returns the
    message (or None); gate mode (``fail=True``, i.e. ``--check``) also
    errors when the guard metric or a baseline is missing — a gate that
    silently checks nothing is worse than no gate.  Returns
    (message_or_None, ok) in gate mode.  ``tol`` defaults to the
    ``BENCH_TOL`` env var (fraction, 0.10) so slower CI hardware can
    loosen the gate without editing code."""
    if tol is None:
        tol = float(os.environ.get('BENCH_TOL', '0.10'))
    new = {name: val for name, val, _ in rows}
    prior = _newest_prior_bench(include_current=fail)

    def _result(msg, ok):
        if msg:
            sys.stderr.write(msg + '\n')
        return (msg, ok) if fail else msg

    if guard not in new:
        if fail:
            return _result(f'[benchmarks] GATE ERROR: guard metric '
                           f'{guard!r} was not produced by this run — '
                           f'did you skip the serving section?', False)
        return _result(None, True)
    if prior is None:
        if fail:
            return _result('[benchmarks] GATE ERROR: no committed '
                           'BENCH_PR*.json baseline to compare against',
                           False)
        return _result(None, True)
    try:
        old = load_bench(prior).get(guard)
        old = float(old) if old is not None else None
        cur = float(new[guard])
    except (TypeError, ValueError):
        old = None
    if not old or old <= 0:
        if fail:
            return _result(f'[benchmarks] GATE ERROR: baseline '
                           f'{os.path.basename(prior)} has no usable '
                           f'{guard!r} value', False)
        return _result(None, True)
    if cur < (1.0 - tol) * old:
        kind = 'FAIL' if fail else 'WARNING'
        return _result(
            f'[benchmarks] {kind}: {guard} regressed '
            f'{(1 - cur / old) * 100:.1f}% vs {os.path.basename(prior)}'
            f' ({old:.3f} -> {cur:.3f} req/s, tolerance {tol:.0%})',
            False)
    if fail:
        return _result(
            f'[benchmarks] gate OK: {guard} {cur:.3f} req/s vs '
            f'{old:.3f} in {os.path.basename(prior)} '
            f'(tolerance {tol:.0%})', True)
    return _result(None, True)


def main() -> None:
    rows = []

    def emit(name, value, unit):
        rows.append((name, value, unit))
        print(f'{name},{value},{unit}', flush=True)

    argv = sys.argv[1:]
    check = '--check' in argv
    names = [a for a in argv if a != '--check'] or list(SECTIONS)
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        sys.exit(f'unknown section(s) {unknown}; pick from {list(SECTIONS)}')
    print('name,value,unit')
    for n in names:
        SECTIONS[n](emit)
    if check:
        # merge gate: compare vs the committed baseline, never persist
        _, ok = check_regression(rows, fail=True)
        sys.exit(0 if ok else 1)
    check_regression(rows)
    # strict JSON on disk: a NaN/inf value (e.g. an unprobed PSNR mean)
    # becomes null instead of a bare NaN token no parser accepts
    from repro.obs.export import sanitize
    doc = sanitize({'sections': names,
                    'rows': [{'name': n, 'value': v, 'unit': u}
                             for n, v, u in rows]})
    with open(BENCH_JSON, 'w') as f:
        json.dump(doc, f, indent=2, allow_nan=False)
        f.write('\n')
    sys.stderr.write(f'[benchmarks] {len(rows)} rows -> {BENCH_JSON}\n')


if __name__ == '__main__':
    main()
