"""§Perf hillclimb driver: run named variants of the three chosen cells and
record roofline deltas.

    PYTHONPATH=src python -m repro.launch.hillclimb --only <variant>

Variants are flag-gated (the framework defaults stay at the recorded
baseline), so every row is reproducible.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json

from repro.configs.registry import get
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig

OUT = os.path.abspath(os.path.join(os.path.dirname(__file__), '..', '..',
                                   '..', 'results', 'perf'))

# (variant_name, arch, shape, cfg_mods, run_cell kwargs)
VARIANTS = [
    # H1: granite train_4k — worst roofline fraction (collective-bound MoE)
    ('h1_granite_train_moefix', 'granite-moe-1b-a400m', 'train_4k',
     {}, {}),
    ('h1_granite_train_eponly', 'granite-moe-1b-a400m', 'train_4k',
     {'model_axis_tp': False}, {}),
    # H2: mistral train_4k — most collective-bound (absolute)
    ('h2_mistral_train_dots', 'mistral-large-123b', 'train_4k',
     {'remat': 'dots'}, {}),
    ('h2_mistral_train_dots_bf16mom', 'mistral-large-123b', 'train_4k',
     {'remat': 'dots'},
     {'opt_cfg': AdamWConfig(moment_dtype='bfloat16')}),
    # H3: deepseek decode_32k — paper-representative (W8A8 + MLA serving)
    ('h3_deepseek_decode_moefix', 'deepseek-v2-lite-16b', 'decode_32k',
     {}, {}),
    ('h3_deepseek_decode_w8a8', 'deepseek-v2-lite-16b', 'decode_32k',
     {}, {'serve_quant': True}),
    ('h3_deepseek_decode_w8a8_eponly', 'deepseek-v2-lite-16b', 'decode_32k',
     {'model_axis_tp': False}, {'serve_quant': True}),
    ('h3_deepseek_decode_w8a8_eponly_seqcache', 'deepseek-v2-lite-16b',
     'decode_32k', {'model_axis_tp': False},
     {'serve_quant': True, 'mla_cache_seq': True}),
    # fixes promoted from the baseline table
    ('fix_jamba_train_bf16mom', 'jamba-1.5-large-398b', 'train_4k',
     {}, {'opt_cfg': AdamWConfig(moment_dtype='bfloat16')}),
    ('fix_deepseek_train_moefix', 'deepseek-v2-lite-16b', 'train_4k',
     {}, {}),
    ('fix_deepseek_train_eponly', 'deepseek-v2-lite-16b', 'train_4k',
     {'model_axis_tp': False}, {}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--only', default=None)
    ap.add_argument('--skip-existing', action='store_true')
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    for name, arch, shape, mods, kw in VARIANTS:
        if args.only and args.only not in name:
            continue
        path = os.path.join(OUT, f'{name}.json')
        if args.skip_existing and os.path.exists(path):
            print(f'skip {name}')
            continue
        cfg = dataclasses.replace(get(arch), **mods)
        print(f'=== {name} ===', flush=True)
        r = run_cell(arch, shape, multi_pod=False, mesh=mesh, cfg=cfg, **kw)
        r['variant'] = name
        with open(path, 'w') as f:
            json.dump(r, f, indent=1)
        rf = r['roofline']
        print(f"    compute={rf['compute_s']:.3g}s memory={rf['memory_s']:.3g}s "
              f"coll={rf['collective_s']:.3g}s dominant={rf['dominant']} "
              f"peak={r['memory']['peak_bytes_per_device']/2**30:.2f}GiB",
              flush=True)


if __name__ == '__main__':
    main()
