"""Production training loop: sharded train step, checkpoint/restart,
preemption handling, straggler monitoring, optional gradient accumulation
and cross-pod gradient compression.

CPU-scale smoke run:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --preset smoke --steps 20 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.configs.registry import REAL_VOCABS, get, smoke_config
from repro.data.pipeline import TokenPipelineConfig, token_batch
from repro.distributed import sharding as SH
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StepMonitor)
from repro.launch import steps as ST
from repro.launch.mesh import make_mesh
from repro.optim.adamw import AdamWConfig, init_adamw


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh, opt_cfg: AdamWConfig,
                 ckpt_dir: Optional[str] = None, real_vocab=None,
                 dtype=jnp.float32, keep: int = 3):
        self.cfg, self.mesh, self.opt_cfg = cfg, mesh, opt_cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep) if ckpt_dir else None
        self.monitor = StepMonitor(n_hosts=max(jax.process_count(), 1))
        self.preempt = PreemptionHandler(install=False)
        self.real_vocab = real_vocab

        params = ST.init_params(jax.random.PRNGKey(0), cfg)
        opt = init_adamw(params)
        self.p_shardings = SH.named(mesh, SH.param_pspecs(params, mesh))
        from jax.sharding import PartitionSpec as P
        o_specs = type(opt)(P(), SH.param_pspecs(opt.m, mesh),
                            SH.param_pspecs(opt.v, mesh))
        self.o_shardings = SH.named(mesh, o_specs)
        with mesh:
            self.params = jax.device_put(params, self.p_shardings)
            self.opt = jax.device_put(opt, self.o_shardings)
        step_fn = ST.build_train_step(cfg, opt_cfg, real_vocab, dtype=dtype)
        # Pin outputs to the same shardings as the inputs: without
        # out_shardings XLA is free to re-layout the updated params (it
        # reshards small stacked leaves over 'data'), which both triggers
        # involuntary full rematerializations inside the partitioner and
        # makes the second call fail the committed-arg sharding check.
        self.step_fn = jax.jit(
            step_fn,
            in_shardings=(self.p_shardings, self.o_shardings, None),
            out_shardings=(self.p_shardings, self.o_shardings, None),
            donate_argnums=(0, 1))
        self.start_step = 0

    def maybe_restore(self):
        """Resume from the latest committed checkpoint (params + optimizer),
        resharding onto the *current* mesh (elastic restart)."""
        if self.ckpt is None:
            return
        step = self.ckpt.latest_step()
        if step is None:
            return
        restored = self.ckpt.restore(
            step, {'params': self.params, 'opt': self.opt},
            {'params': self.p_shardings, 'opt': self.o_shardings})
        self.params, self.opt = restored['params'], restored['opt']
        self.start_step = step
        print(f'[train] resumed from step {step}')

    def save(self, step: int, blocking: bool = False):
        if self.ckpt is not None:
            self.ckpt.save(step, {'params': self.params, 'opt': self.opt},
                           blocking=blocking,
                           extra_meta={'arch': self.cfg.name})

    def run(self, data_cfg: TokenPipelineConfig, steps: int,
            ckpt_every: int = 50, log_every: int = 10):
        losses = []
        host = max(jax.process_index(), 0)
        with self.mesh:
            for step in range(self.start_step, steps):
                t0 = time.time()
                batch = token_batch(data_cfg, step)
                self.params, self.opt, metrics = self.step_fn(
                    self.params, self.opt, batch)
                loss = float(metrics['loss'])
                losses.append(loss)
                self.monitor.record(host, time.time() - t0)
                if step % log_every == 0:
                    print(f'[train] step={step} loss={loss:.4f} '
                          f'gnorm={float(metrics["grad_norm"]):.3f} '
                          f'dt={time.time()-t0:.2f}s', flush=True)
                if self.ckpt and step and step % ckpt_every == 0:
                    self.save(step)
                if self.preempt.preempted:
                    print('[train] preemption: sync checkpoint + exit')
                    self.save(step, blocking=True)
                    return losses
                rep = self.monitor.check()
                if rep is not None:
                    print(f'[train] straggler: {rep.recommendation}')
        if self.ckpt:
            self.save(steps, blocking=True)
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True)
    ap.add_argument('--preset', default='smoke', choices=['smoke', 'full'])
    ap.add_argument('--steps', type=int, default=50)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=64)
    ap.add_argument('--lr', type=float, default=1e-3)
    ap.add_argument('--ckpt', default=None)
    ap.add_argument('--mesh-shape', default='1,1')
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.preset == 'smoke' \
        else get(args.arch)
    shape = tuple(int(x) for x in args.mesh_shape.split(','))
    axes = ('data', 'model')[:len(shape)] if len(shape) <= 2 else \
        ('pod', 'data', 'model')
    mesh = make_mesh(shape, axes)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    tr = Trainer(cfg, mesh, opt_cfg, ckpt_dir=args.ckpt)
    tr.maybe_restore()
    data_cfg = TokenPipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                                   global_batch=args.batch)
    losses = tr.run(data_cfg, args.steps)
    print(f'[train] done. loss {losses[0]:.3f} -> {losses[-1]:.3f}')


if __name__ == '__main__':
    main()
