"""Jittable train / prefill / decode step builders, shared by the training
loop, the serving loop, and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw


def init_params(key, cfg: ArchConfig):
    if cfg.family == 'encdec':
        return ED.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def build_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                     real_vocab: Optional[int] = None,
                     dtype=jnp.bfloat16) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            if cfg.family == 'encdec':
                return ED.encdec_loss(p, cfg, batch['frames'],
                                      batch['tokens'], batch['labels'],
                                      dtype=dtype, real_vocab=real_vocab)
            return T.lm_loss(p, cfg, batch['tokens'], batch['labels'],
                             dtype=dtype, real_vocab=real_vocab)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        return new_params, new_opt, {'loss': loss, 'grad_norm': gnorm}

    return train_step


def init_serve_state(cfg: ArchConfig, batch: int, max_len: int,
                     cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    if cfg.family == 'encdec':
        enc_len = min(max_len, 4096)
        return {'cache': ED.init_dec_cache(cfg, batch, max_len, cache_dtype),
                'memory': jnp.zeros((batch, enc_len, cfg.d_model),
                                    cache_dtype)}
    return {'cache': T.init_lm_cache(cfg, batch, max_len, cache_dtype)}


def build_prefill_step(cfg: ArchConfig, dtype=jnp.bfloat16,
                       quant: bool = False) -> Callable:
    """(params, serve_state, batch) -> (next_token, serve_state)."""

    def prefill(params, state, batch):
        if cfg.family == 'encdec':
            logits, cache, memory = ED.encdec_prefill(
                params, cfg, batch['frames'], batch['tokens'],
                state['cache'], dtype=dtype)
            state = {'cache': cache, 'memory': memory.astype(
                state['memory'].dtype)}
        else:
            logits, cache = T.lm_prefill(params, cfg, batch['tokens'],
                                         state['cache'], dtype=dtype,
                                         quant=quant)
            state = {'cache': cache}
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return prefill


def build_decode_step(cfg: ArchConfig, dtype=jnp.bfloat16,
                      quant: bool = False) -> Callable:
    """(params, serve_state, token (B,1), pos ()) -> (token, serve_state)."""

    def decode(params, state, token, pos):
        if cfg.family == 'encdec':
            logits, cache = ED.encdec_decode(params, cfg, token,
                                             state['cache'], pos,
                                             state['memory'], dtype=dtype)
            state = dict(state, cache=cache)
        else:
            logits, cache = T.lm_decode(params, cfg, token, state['cache'],
                                        pos, dtype=dtype, quant=quant)
            state = dict(state, cache=cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return decode


def make_batch_struct(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one training batch (input_specs)."""
    B, S = shape.global_batch, shape.seq_len
    batch = {'tokens': jax.ShapeDtypeStruct((B, S), jnp.int32),
             'labels': jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == 'encdec':
        batch['frames'] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    return batch
