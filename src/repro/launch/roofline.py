"""Roofline report: read the dry-run JSONs and produce the EXPERIMENTS.md
tables (three terms per cell, dominant bottleneck, MODEL_FLOPS ratio).

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get

# analytic parameter counts (computed once via eval_shape, cached here by
# the report generator)


def count_params(arch_name: str) -> int:
    import jax
    import numpy as np
    from repro.launch.steps import init_params
    cfg = get(arch_name)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return sum(int(np.prod(s.shape)) for s in
               jax.tree_util.tree_leaves(shapes))


def active_params(arch_name: str, total: int) -> int:
    """MoE: 6*N_active*D — activated params per token."""
    cfg = get(arch_name)
    if cfg.moe is None and cfg.family != 'hybrid':
        return total
    import jax
    import numpy as np
    from repro.launch.steps import init_params
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0),
                                                get(arch_name)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    act = 0
    for path, leaf in flat:
        p = '/'.join(str(getattr(e, 'key', getattr(e, 'idx', ''))) for e in path)
        n = int(np.prod(leaf.shape))
        if any(k in p for k in ('w_gate', 'w_up', 'w_down')):
            m = cfg.moe
            n = n * m.top_k // m.n_experts
        act += n
    return act


def model_flops(arch_name: str, shape_name: str, n_active: int) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode."""
    s = SHAPES[shape_name]
    tokens = s.global_batch * (s.seq_len if s.kind != 'decode' else 1)
    mult = 6.0 if s.kind == 'train' else 2.0
    return mult * n_active * tokens


def load_cells(result_dir: str, mesh_tag: str = 'singlepod'):
    cells = {}
    for path in sorted(glob.glob(os.path.join(result_dir,
                                              f'*__{mesh_tag}.json'))):
        r = json.load(open(path))
        cells[(r['arch'], r['shape'])] = r
    return cells


def report(result_dir: str, mesh_tag: str = 'singlepod',
           with_params: bool = True) -> str:
    cells = load_cells(result_dir, mesh_tag)
    lines = []
    lines.append(
        '| arch | shape | compute s | memory s | coll s | dominant | '
        'peak GiB/dev | MODEL_FLOPS/HLO | note |')
    lines.append('|---|---|---|---|---|---|---|---|---|')
    n_cache: Dict[str, int] = {}
    for (arch, shape), r in sorted(cells.items()):
        rf = r['roofline']
        dev = r['devices']
        ratio = ''
        note = ''
        if with_params:
            if arch not in n_cache:
                total = count_params(arch)
                n_cache[arch] = active_params(arch, total)
            mf = model_flops(arch, shape, n_cache[arch])
            hlo_global = r['cost']['flops_per_device'] * dev
            if hlo_global > 0:
                ratio = f'{mf / hlo_global:.2f}'
        dom = rf['dominant'].replace('_s', '')
        peak = r['memory']['peak_bytes_per_device'] / 2 ** 30
        if peak > 16:
            note = 'OVER 16GiB v5e budget'
        lines.append(
            f'| {arch} | {shape} | {rf["compute_s"]:.3g} | '
            f'{rf["memory_s"]:.3g} | {rf["collective_s"]:.3g} | {dom} | '
            f'{peak:.2f} | {ratio} | {note} |')
    return '\n'.join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--dir', default=os.path.join(
        os.path.dirname(__file__), '..', '..', '..', 'results', 'dryrun'))
    ap.add_argument('--mesh', default='singlepod')
    ap.add_argument('--no-params', action='store_true')
    args = ap.parse_args()
    print(report(os.path.abspath(args.dir), args.mesh,
                 with_params=not args.no_params))


if __name__ == '__main__':
    main()
