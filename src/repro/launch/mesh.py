"""Production mesh construction.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.  The ``pod`` axis is
pure data parallelism across pods (cross-pod traffic = one gradient
all-reduce per step, the only collective that crosses DCI).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / elastic restarts / smoke runs)."""
    return jax.make_mesh(shape, axes)


def serving_mesh(n_devices: Optional[int] = None,
                 devices=None) -> Mesh:
    """1-D ``('data',)`` mesh for the slot-sharded serving engine.

    ``n_devices=None`` takes every visible device; an explicit count
    takes the first N (the elastic-resize path passes the surviving
    device list instead).  Tests get 8 CPU "devices" from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    devs = list(devices) if devices is not None else jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devs):
            raise ValueError(f'need 1..{len(devs)} devices, '
                             f'got {n_devices}')
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), ('data',))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh: ('pod','data') when a pod axis
    exists, else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ('pod', 'data'))


def mesh_dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def mesh_model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get('model', 1))
