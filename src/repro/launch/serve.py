"""Serving loop: batched LM decode (prefill + N decode steps) or
continuous-batching diffusion generation, with per-request precision
policies (paper C1: the W8A8 photonic path).

CPU-scale demos:
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --preset smoke --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --diffusion \
        --requests 8 --rate 4 --slots 4 --steps 6 --precision w8a8

The diffusion mode replays a Poisson arrival trace through the
continuous-batching engine (``repro.serving``): requests arrive with
exponential inter-arrival times at ``--rate`` req/s, are multiplexed
into mixed-timestep UNet steps, and report p50/p95 latency, requests/s
and the per-request energy.  ``--precision`` selects each request's
execution policy — ``fp32`` (GPU digital baseline energy), ``w8a8``
(the analog MR-bank path, ~94x lower EPB) or ``w8a8+noise`` (8-bit plus
the analog perturbation model); quantized runs also print the PSNR/MSE
quality probe against the fp32 reference (the accuracy-vs-EPB frontier).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get, smoke_config
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_mesh


def serve_lm(cfg, mesh, batch: int, prompt_len: int, new_tokens: int,
             quant: bool = False, dtype=jnp.float32):
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + new_tokens
    state = ST.init_serve_state(cfg, batch, max_len, cache_dtype=dtype)
    prefill = jax.jit(ST.build_prefill_step(cfg, dtype=dtype, quant=quant))
    decode = jax.jit(ST.build_decode_step(cfg, dtype=dtype, quant=quant),
                     donate_argnums=(1,))
    rng = np.random.default_rng(0)
    batch_in = {'tokens': jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == 'encdec':
        batch_in['frames'] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), dtype)
    with mesh:
        t0 = time.perf_counter()
        tok, state = prefill(params, state, batch_in)
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0
        out = [tok]
        t0 = time.perf_counter()
        for i in range(new_tokens - 1):
            tok, state = decode(params, state, tok,
                                jnp.int32(prompt_len + i))
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    tps = batch * (new_tokens - 1) / max(t_decode, 1e-9)
    print(f'[serve] prefill {prompt_len} toks x{batch}: {t_prefill:.3f}s; '
          f'decode {new_tokens-1} steps: {t_decode:.3f}s '
          f'({tps:.1f} tok/s)')
    return seqs


def poisson_trace(n: int, rate_hz: float, steps: int, seed: int = 0,
                  slo_ms=None, precision: str = 'fp32'):
    """Poisson arrival trace: n requests, exponential inter-arrivals."""
    from repro.serving import GenerationRequest
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    return [GenerationRequest(request_id=i, seed=1000 + i, steps=steps,
                              arrival_time=float(a), slo_ms=slo_ms,
                              precision=precision)
            for i, a in enumerate(arrivals)]


def serve_diffusion(img: int, steps: int, n_requests: int, rate_hz: float,
                    slots: int, precision: str = 'fp32', seed: int = 0,
                    slo_ms=None, quality_probe: int = 1,
                    cache_interval: int = 1, exit_tol=None,
                    exit_patience: int = 2):
    """Replay a Poisson arrival trace through the continuous-batching
    engine and print the serving + energy report, plus the per-policy
    accuracy-vs-EPB frontier.  ``cache_interval > 1`` enables
    DeepCache-phased slotting (full UNet pass every ``cache_interval``
    ticks, shallow passes in between); ``exit_tol`` enables speculative
    early-exit draining once a request's x0 prediction stops moving."""
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.serving import ContinuousBatchingEngine

    cfg = UNetConfig('serve-diffusion', img_size=img, in_ch=3, base_ch=64,
                     ch_mults=(1, 2), n_res_blocks=1,
                     attn_resolutions=(img // 2,), n_heads=4, timesteps=100)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    engine = ContinuousBatchingEngine(pipe, slots=slots,
                                      quality_probe=quality_probe,
                                      cache_interval=cache_interval,
                                      exit_tol=exit_tol,
                                      exit_patience=exit_patience)
    print(f'[serve] warmup (compile, policy={precision})...', flush=True)
    engine.warmup(precisions=(precision,))
    trace = poisson_trace(n_requests, rate_hz, steps, seed, slo_ms=slo_ms,
                          precision=precision)
    sched = []
    if cache_interval > 1:
        sched.append(f'cache_interval={cache_interval}')
    if exit_tol is not None and exit_tol > 0:
        sched.append(f'exit_tol={exit_tol:g} patience={exit_patience}')
    print(f'[serve] replaying {n_requests} requests at {rate_hz:.1f} req/s '
          f'({slots} slots, {steps} DDIM steps, precision={precision}'
          + (', ' + ', '.join(sched) if sched else '') + ')', flush=True)
    t0 = time.perf_counter()
    results = engine.replay(trace)
    makespan = time.perf_counter() - t0
    s = engine.metrics.summary()
    print(f'[serve] {len(results)} done in {makespan:.2f}s '
          f'({s["requests_per_s"]:.2f} req/s) '
          f'p50={s["p50_latency_ms"]:.0f}ms p95={s["p95_latency_ms"]:.0f}ms '
          f'slo_viol={int(s["slo_violations"])} shed={int(s["shed"])}')
    if cache_interval > 1 or s['steps_saved'] > 0:
        print(f'[sched] cache_hit_rate={s["cache_hit_rate"]:.2f} '
              f'early_exits={int(s["early_exits"])} '
              f'steps_saved={int(s["steps_saved"])}')
    src = 'simulated DiffLight' if precision != 'fp32' \
        else 'GPU digital baseline'
    print(f'[energy] {s["energy_per_request_mj"]:.2f} mJ/request '
          f'({s["total_energy_mj"]:.1f} mJ total, {src})')
    for name, pt in engine.metrics.frontier().items():
        quality = '' if pt['probed'] == 0 else (
            f'  psnr={pt["mean_psnr_db"]:.1f}dB mse={pt["mean_mse"]:.2e}'
            f' (vs fp32 reference, {int(pt["probed"])} probed)')
        sched_cols = ''
        if pt['cache_hit_rate'] > 0 or pt['early_exits'] > 0:
            sched_cols = (f'  hit_rate={pt["cache_hit_rate"]:.2f}'
                          f' steps={pt["mean_steps_executed"]:.1f}'
                          f'/{pt["mean_steps_requested"]:.1f}')
        print(f'[frontier] {name}: {pt["mean_epb_pj"]:.3f} pJ/bit  '
              f'{pt["mean_energy_j"] * 1e3:.2f} mJ/request'
              f'{sched_cols}{quality}')
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='internlm2-1.8b')
    ap.add_argument('--preset', default='smoke', choices=['smoke', 'full'])
    ap.add_argument('--batch', type=int, default=2)
    ap.add_argument('--prompt', type=int, default=16)
    ap.add_argument('--tokens', type=int, default=16)
    ap.add_argument('--w8a8', action='store_true',
                    help='LM mode: quantized matmuls; diffusion mode: '
                         'deprecated alias for --precision w8a8')
    ap.add_argument('--precision', default=None,
                    choices=['fp32', 'w8a8', 'w8a8+noise'],
                    help='diffusion request precision policy '
                         '(default fp32; overrides --w8a8)')
    ap.add_argument('--quality-probe', type=int, default=1,
                    help='probe every k-th quantized request against the '
                         'fp32 reference (0 = off)')
    ap.add_argument('--diffusion', action='store_true',
                    help='serve diffusion requests (continuous batching)')
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--rate', type=float, default=4.0,
                    help='Poisson arrival rate, req/s')
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--steps', type=int, default=6,
                    help='DDIM steps per request (diffusion mode)')
    ap.add_argument('--img', type=int, default=16)
    ap.add_argument('--slo-ms', type=float, default=None)
    ap.add_argument('--cache-interval', type=int, default=1,
                    help='DeepCache refresh cadence: full UNet pass every '
                         'k ticks, shallow cached passes in between '
                         '(1 = caching off)')
    ap.add_argument('--exit-tol', type=float, default=None,
                    help='speculative early exit: drain a request once its '
                         'x0 prediction moves less than this relative '
                         'tolerance (None/0 = off)')
    ap.add_argument('--exit-patience', type=int, default=2,
                    help='consecutive converged ticks before early exit')
    args = ap.parse_args()
    if args.diffusion:
        precision = args.precision or ('w8a8' if args.w8a8 else 'fp32')
        serve_diffusion(args.img, args.steps, args.requests, args.rate,
                        args.slots, precision=precision, slo_ms=args.slo_ms,
                        quality_probe=args.quality_probe,
                        cache_interval=args.cache_interval,
                        exit_tol=args.exit_tol,
                        exit_patience=args.exit_patience)
        return
    cfg = smoke_config(args.arch) if args.preset == 'smoke' \
        else get(args.arch)
    mesh = make_mesh((1, 1), ('data', 'model'))
    seqs = serve_lm(cfg, mesh, args.batch, args.prompt, args.tokens,
                    quant=args.w8a8)
    print('[serve] sample token ids:', np.asarray(seqs[0, :12]))


if __name__ == '__main__':
    main()
