"""Serving loop: batched LM decode (prefill + N decode steps) or
continuous-batching diffusion generation, with per-request precision
policies (paper C1: the W8A8 photonic path).

CPU-scale demos:
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --preset smoke --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --diffusion \
        --requests 8 --rate 4 --slots 4 --steps 6 --precision w8a8

The diffusion mode replays a Poisson arrival trace through the
continuous-batching engine (``repro.serving``): requests arrive with
exponential inter-arrival times at ``--rate`` req/s, are multiplexed
into mixed-timestep UNet steps, and report p50/p95 latency, requests/s
and the per-request energy.  ``--precision`` selects each request's
execution policy — ``fp32`` (GPU digital baseline energy), ``w8a8``
(the analog MR-bank path, ~94x lower EPB) or ``w8a8+noise`` (8-bit plus
the analog perturbation model); quantized runs also print the PSNR/MSE
quality probe against the fp32 reference (the accuracy-vs-EPB frontier).

Cold-start and overload hardening:

``--cache-dir PATH`` routes every XLA compilation through JAX's
persistent on-disk cache, so a restarted server *loads* its step
variants instead of recompiling them — the warmup line reports the wall
seconds and whether the cache was warm.  ``--overload X`` sizes the
arrival rate at X times the engine's *measured* service capacity
(``engine.measure_tick_s``), bounds the admission queue
(``--queue-depth``, default 2x slots) and turns on deadline-aware
shedding, then proves survival: the queue stays bounded, excess load is
shed (by cause), no deadline-dead request occupies a slot, and the
p50/p99 queue waits are reported:

    PYTHONPATH=src python -m repro.launch.serve --diffusion \
        --overload 5 --requests 32 --slots 4 --steps 6 \
        --cache-dir /tmp/repro-xla-cache

Sharded multi-device serving: ``--devices N`` builds a 1-D ``('data',)``
mesh over the first N visible devices and shards the engine's slot axis
across it (``--slots-per-device`` fixes the per-device budget; decode
overlap is on by default, ``--overlap-decode off`` disables it).
``--resize-to M --resize-after K`` triggers an elastic resize to M
devices after K completions, mid-replay — the drop-and-survive demo.
``--cache-max-mb`` bounds the persistent compilation cache with LRU
eviction.  Simulate a mesh on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --diffusion \
        --devices 8 --slots-per-device 1 --requests 16 --rate 8 \
        --steps 6 --resize-to 4 --resize-after 4

Observability (``repro.obs``): ``--trace PATH`` records every request's
lifecycle (submit -> slot assign -> per-tick steps -> early exit ->
decode -> complete, plus sheds, warmup, resizes, stragglers) and writes
a Chrome/Perfetto ``trace_event`` timeline; ``--log-json PATH`` writes
the same events as a grep-able JSONL structured log; ``--prom PATH``
dumps the Prometheus text exposition of the final counters; and
``--report-every S`` prints an in-run metrics snapshot line every S
seconds.  After a traced replay the trace is reconciled against
``ServingMetrics`` (same completed/shed counts, identical latencies)
before it is written.  ``--log-level`` tunes verbosity; log lines keep
their ``[serve]`` / ``[mesh]`` / ``[overload]`` prefixes as logger
names:

    PYTHONPATH=src python -m repro.launch.serve --diffusion \
        --requests 8 --rate 4 --slots 4 --steps 6 \
        --trace /tmp/serve-trace.json --log-json /tmp/serve-events.jsonl
"""
from __future__ import annotations

import argparse
import logging
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get, smoke_config
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_mesh

log_serve = logging.getLogger('serve')
log_mesh = logging.getLogger('mesh')
log_coldstart = logging.getLogger('coldstart')
log_overload = logging.getLogger('overload')
log_elastic = logging.getLogger('elastic')
log_sched = logging.getLogger('sched')
log_energy = logging.getLogger('energy')
log_frontier = logging.getLogger('frontier')
log_obs = logging.getLogger('obs')


def setup_logging(level: str = 'info', stream=None) -> None:
    """Leveled stdout logging with the historical ``[tag]`` prefixes:
    each subsystem logs through its own logger (``serve``, ``mesh``,
    ``overload``, ...) and the formatter renders the logger name as the
    line prefix, so ``--log-level debug`` tunes verbosity without
    changing the grep-able output shape."""
    logging.basicConfig(
        level=getattr(logging, level.upper()),
        format='[%(name)s] %(message)s',
        stream=stream if stream is not None else sys.stdout,
        force=True)


def serve_lm(cfg, mesh, batch: int, prompt_len: int, new_tokens: int,
             quant: bool = False, dtype=jnp.float32):
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + new_tokens
    state = ST.init_serve_state(cfg, batch, max_len, cache_dtype=dtype)
    prefill = jax.jit(ST.build_prefill_step(cfg, dtype=dtype, quant=quant))
    decode = jax.jit(ST.build_decode_step(cfg, dtype=dtype, quant=quant),
                     donate_argnums=(1,))
    rng = np.random.default_rng(0)
    batch_in = {'tokens': jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == 'encdec':
        batch_in['frames'] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), dtype)
    with mesh:
        t0 = time.perf_counter()
        tok, state = prefill(params, state, batch_in)
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0
        out = [tok]
        t0 = time.perf_counter()
        for i in range(new_tokens - 1):
            tok, state = decode(params, state, tok,
                                jnp.int32(prompt_len + i))
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    tps = batch * (new_tokens - 1) / max(t_decode, 1e-9)
    log_serve.info('prefill %d toks x%d: %.3fs; decode %d steps: %.3fs '
                   '(%.1f tok/s)', prompt_len, batch, t_prefill,
                   new_tokens - 1, t_decode, tps)
    return seqs


def poisson_trace(n: int, rate_hz: float, steps: int, seed: int = 0,
                  slo_ms=None, precision: str = 'fp32'):
    """Poisson arrival trace: n requests, exponential inter-arrivals."""
    from repro.serving import GenerationRequest
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    return [GenerationRequest(request_id=i, seed=1000 + i, steps=steps,
                              arrival_time=float(a), slo_ms=slo_ms,
                              precision=precision)
            for i, a in enumerate(arrivals)]


def serve_diffusion(img: int, steps: int, n_requests: int, rate_hz: float,
                    slots: int, precision: str = 'fp32', seed: int = 0,
                    slo_ms=None, quality_probe: int = 1,
                    cache_interval: int = 1, exit_tol=None,
                    exit_patience: int = 2, cache_dir=None,
                    queue_depth=None, shed_policy: str = 'reject-newest',
                    overload: float = 0.0, devices=None,
                    slots_per_device=None, overlap_decode=None,
                    resize_to=None, resize_after=None, cache_max_mb=None,
                    trace_path=None, log_json_path=None, prom_path=None,
                    report_every=None):
    """Replay a Poisson arrival trace through the continuous-batching
    engine and print the serving + energy report, plus the per-policy
    accuracy-vs-EPB frontier.  ``cache_interval > 1`` enables
    DeepCache-phased slotting (full UNet pass every ``cache_interval``
    ticks, shallow passes in between); ``exit_tol`` enables speculative
    early-exit draining once a request's x0 prediction stops moving.

    ``cache_dir`` wires the persistent compilation cache into warmup
    (cold run populates it; a restarted process loads from it).
    ``overload > 0`` ignores ``rate_hz`` and offers ``overload`` times
    the engine's measured service capacity, with a bounded queue
    (``queue_depth``, default ``2 * slots``) and deadline-aware
    shedding proving the engine survives instead of growing its backlog
    without bound.

    ``devices`` shards the slot axis over a 1-D mesh of the first N
    visible devices; ``resize_to``/``resize_after`` demo the elastic
    path by resizing the mesh mid-replay after K completions.

    ``trace_path`` / ``log_json_path`` enable per-request tracing and
    write the Chrome-trace timeline / JSONL structured log after the
    replay (reconciled against the metrics first); ``prom_path`` dumps
    the final Prometheus text exposition; ``report_every`` emits an
    in-run snapshot line every that-many seconds."""
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    from repro.obs import (SnapshotReporter, Tracer, render_exposition,
                           write_chrome_trace, write_jsonl)
    from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                               cache_entries, enable_persistent_cache,
                               overload_factor)

    cfg = UNetConfig('serve-diffusion', img_size=img, in_ch=3, base_ch=64,
                     ch_mults=(1, 2), n_res_blocks=1,
                     attn_resolutions=(img // 2,), n_heads=4, timesteps=100)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    queue = None
    if overload > 0:
        queue_depth = 2 * slots if queue_depth is None else queue_depth
        shed_policy = 'deadline-aware'
    if queue_depth is not None or shed_policy != 'reject-newest':
        queue = AdmissionQueue(max_depth=queue_depth,
                               shed_policy=shed_policy)
    mesh = None
    if devices is not None:
        from repro.launch.mesh import serving_mesh
        mesh = serving_mesh(n_devices=devices)
    tracer = Tracer() if (trace_path or log_json_path) else None
    reporter = None
    if report_every is not None and report_every > 0:
        reporter = SnapshotReporter(interval_s=report_every,
                                    emit=log_obs.info)

    def _on_straggler(report):
        log_mesh.warning('straggler flagged: hosts %s (median %.1fms, '
                         'threshold %.1fms) — %s', list(report.slow_hosts),
                         report.median_s * 1e3, report.threshold_s * 1e3,
                         report.recommendation)

    engine = ContinuousBatchingEngine(pipe, slots=slots, queue=queue,
                                      quality_probe=quality_probe,
                                      cache_interval=cache_interval,
                                      exit_tol=exit_tol,
                                      exit_patience=exit_patience,
                                      mesh=mesh,
                                      slots_per_device=slots_per_device,
                                      overlap_decode=overlap_decode,
                                      tracer=tracer, reporter=reporter,
                                      on_straggler=_on_straggler
                                      if mesh is not None else None)
    if mesh is not None:
        log_mesh.info('slot axis sharded over %d devices: %d slots '
                      '(%d/device), overlap_decode=%s', devices,
                      engine.slots, engine.slots // devices,
                      engine.overlap_decode)
    if cache_dir and cache_max_mb is not None:
        # enable with the size bound BEFORE warmup re-enables it (the
        # bound is process state the engine's trim_cache calls enforce)
        enable_persistent_cache(cache_dir,
                                max_bytes=int(cache_max_mb * 2 ** 20))
    entries_before = cache_entries(cache_dir) if cache_dir else 0
    log_serve.info('warmup (compile, policy=%s%s)...', precision,
                   f', cache_dir={cache_dir}' if cache_dir else '')
    warmup_s = engine.warmup(precisions=(precision,), cache_dir=cache_dir)
    if cache_dir:
        entries = cache_entries(cache_dir)
        state = 'warm (loaded from cache)' if entries_before > 0 \
            else f'cold (persisted {entries} executables)'
        log_coldstart.info('warmup %.2fs — %s', warmup_s, state)
    else:
        log_coldstart.info('warmup %.2fs (no persistent cache)', warmup_s)
    if overload > 0:
        tick_s = engine.measure_tick_s(steps=steps)
        capacity_rps = slots / (steps * tick_s)
        rate_hz = overload * capacity_rps
        if slo_ms is None:
            # default SLO: 3x the zero-queue service time — generous for
            # an uncontended request, certain to shed under overload
            slo_ms = 3.0 * steps * tick_s * 1e3
        log_overload.info(
            'measured capacity %.2f req/s (%.1f ms/tick) -> offering '
            '%.2f req/s = %.1fx, queue_depth=%s, slo=%.0fms, '
            'shed_policy=%s', capacity_rps, tick_s * 1e3, rate_hz,
            overload_factor(rate_hz, tick_s, steps, slots), queue_depth,
            slo_ms, shed_policy)
    trace = poisson_trace(n_requests, rate_hz, steps, seed, slo_ms=slo_ms,
                          precision=precision)
    sched = []
    if cache_interval > 1:
        sched.append(f'cache_interval={cache_interval}')
    if exit_tol is not None and exit_tol > 0:
        sched.append(f'exit_tol={exit_tol:g} patience={exit_patience}')
    log_serve.info('replaying %d requests at %.1f req/s (%d slots, %d '
                   'DDIM steps, precision=%s%s)', n_requests, rate_hz,
                   engine.slots, steps, precision,
                   ', ' + ', '.join(sched) if sched else '')
    resize_state = {'done': 0, 'fired': False, 'flushed': []}

    def _on_result(res):
        resize_state['done'] += 1
        k = resize_after if resize_after is not None else n_requests // 2
        if (resize_to is not None and not resize_state['fired']
                and resize_state['done'] >= k):
            resize_state['fired'] = True
            log_elastic.info('%d done -> resizing %s -> %d devices '
                             'mid-replay', resize_state['done'], devices,
                             resize_to)
            resize_state['flushed'].extend(engine.elastic_resize(
                n_devices=resize_to, precisions=(precision,)))
            log_elastic.info('rebuilt: %d slots on %d devices, %d parked',
                             engine.slots, resize_to, len(engine._parked))

    t0 = time.perf_counter()
    results = engine.replay(
        trace, on_result=_on_result if resize_to is not None else None)
    results.extend(resize_state['flushed'])
    makespan = time.perf_counter() - t0
    if engine.monitor is not None:
        report = engine.monitor.check()
        log_mesh.info('stragglers: %s',
                      report.recommendation if report else 'none detected')
    s = engine.metrics.summary()
    log_serve.info('%d done in %.2fs (%.2f req/s) p50=%.0fms p95=%.0fms '
                   'p99=%.0fms slo_viol=%d shed=%d', len(results),
                   makespan, s['requests_per_s'], s['p50_latency_ms'],
                   s['p95_latency_ms'], s['p99_latency_ms'],
                   int(s['slo_violations']), int(s['shed']))
    if overload > 0 or s['shed'] > 0:
        m = engine.metrics
        by = dict(m.shed_by_reason)
        log_overload.info(
            'survived: queue peaked at %d%s, shed %d/%d (queue_full=%d '
            'evicted=%d expired=%d), queue wait p50=%.0fms p99=%.0fms',
            int(s['max_queue_depth']),
            f'/{queue_depth}' if queue_depth is not None else '',
            int(s['shed']), n_requests, by.get('queue_full', 0),
            by.get('deadline_evict', 0), by.get('expired', 0),
            s['p50_queue_wait_ms'], s['p99_queue_wait_ms'])
        assert len(results) + int(s['shed']) == n_requests, \
            'requests lost: completed + shed != offered'
        if queue_depth is not None:
            assert s['max_queue_depth'] <= queue_depth, 'queue bound broken'
    if cache_interval > 1 or s['steps_saved'] > 0:
        log_sched.info('cache_hit_rate=%.2f early_exits=%d steps_saved=%d',
                       s['cache_hit_rate'], int(s['early_exits']),
                       int(s['steps_saved']))
    src = 'simulated DiffLight' if precision != 'fp32' \
        else 'GPU digital baseline'
    log_energy.info('%.2f mJ/request (%.1f mJ total, %s)',
                    s['energy_per_request_mj'], s['total_energy_mj'], src)
    for name, pt in engine.metrics.frontier().items():
        quality = '' if pt['probed'] == 0 else (
            f'  psnr={pt["mean_psnr_db"]:.1f}dB mse={pt["mean_mse"]:.2e}'
            f' (vs fp32 reference, {int(pt["probed"])} probed)')
        sched_cols = ''
        if pt['cache_hit_rate'] > 0 or pt['early_exits'] > 0:
            sched_cols = (f'  hit_rate={pt["cache_hit_rate"]:.2f}'
                          f' steps={pt["mean_steps_executed"]:.1f}'
                          f'/{pt["mean_steps_requested"]:.1f}')
        log_frontier.info('%s: %.3f pJ/bit  %.2f mJ/request%s%s', name,
                          pt['mean_epb_pj'], pt['mean_energy_j'] * 1e3,
                          sched_cols, quality)
    if tracer is not None:
        _reconcile_trace(tracer, engine)
        if trace_path:
            n = write_chrome_trace(tracer, trace_path)
            log_obs.info('chrome trace: %d events -> %s (open in '
                         'chrome://tracing or ui.perfetto.dev)', n,
                         trace_path)
        if log_json_path:
            n = write_jsonl(tracer, log_json_path)
            log_obs.info('structured event log: %d lines -> %s', n,
                         log_json_path)
    if prom_path:
        with open(prom_path, 'w') as f:
            f.write(render_exposition(engine.metrics))
        log_obs.info('prometheus exposition -> %s', prom_path)
    return results


def _reconcile_trace(tracer, engine) -> None:
    """Assert the trace agrees with the metrics ledger before export:
    one request span per completed request (with the span duration equal
    to the result latency by construction — spans are stamped from the
    result's own timing fields), one shed instant per shed request."""
    m = engine.metrics
    spans = tracer.spans('request')
    assert len(spans) == m.completed, \
        f'trace/metrics drift: {len(spans)} request spans vs ' \
        f'{m.completed} completed'
    sheds = tracer.select('shed')
    total_shed = sum(m.shed_by_reason.values())
    assert len(sheds) == total_shed, \
        f'trace/metrics drift: {len(sheds)} shed events vs ' \
        f'{total_shed} shed requests'
    log_obs.info('trace reconciled: %d request spans == %d completed, '
                 '%d shed events == %d shed (%d events total)',
                 len(spans), m.completed, len(sheds), total_shed,
                 len(tracer))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='internlm2-1.8b')
    ap.add_argument('--preset', default='smoke', choices=['smoke', 'full'])
    ap.add_argument('--batch', type=int, default=2)
    ap.add_argument('--prompt', type=int, default=16)
    ap.add_argument('--tokens', type=int, default=16)
    ap.add_argument('--w8a8', action='store_true',
                    help='LM mode: quantized matmuls; diffusion mode: '
                         'deprecated alias for --precision w8a8')
    ap.add_argument('--precision', default=None,
                    choices=['fp32', 'w8a8', 'w8a8+noise'],
                    help='diffusion request precision policy '
                         '(default fp32; overrides --w8a8)')
    ap.add_argument('--quality-probe', type=int, default=1,
                    help='probe every k-th quantized request against the '
                         'fp32 reference (0 = off)')
    ap.add_argument('--diffusion', action='store_true',
                    help='serve diffusion requests (continuous batching)')
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--rate', type=float, default=4.0,
                    help='Poisson arrival rate, req/s')
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--steps', type=int, default=6,
                    help='DDIM steps per request (diffusion mode)')
    ap.add_argument('--img', type=int, default=16)
    ap.add_argument('--slo-ms', type=float, default=None)
    ap.add_argument('--cache-interval', type=int, default=1,
                    help='DeepCache refresh cadence: full UNet pass every '
                         'k ticks, shallow cached passes in between '
                         '(1 = caching off)')
    ap.add_argument('--exit-tol', type=float, default=None,
                    help='speculative early exit: drain a request once its '
                         'x0 prediction moves less than this relative '
                         'tolerance (None/0 = off)')
    ap.add_argument('--exit-patience', type=int, default=2,
                    help='consecutive converged ticks before early exit')
    ap.add_argument('--cache-dir', default=None,
                    help='persistent XLA compilation cache directory: a '
                         'restarted server loads its compiled step '
                         'variants from here instead of recompiling')
    ap.add_argument('--queue-depth', type=int, default=None,
                    help='bound the admission queue (default: unbounded; '
                         '--overload defaults this to 2x slots)')
    ap.add_argument('--shed-policy', default='reject-newest',
                    choices=['reject-newest', 'deadline-aware'],
                    help='what to shed at the queue bound: the newest '
                         'arrival, or the entry with the least SLO slack')
    ap.add_argument('--overload', type=float, default=0.0,
                    help='offer this multiple of the measured service '
                         'capacity (ignores --rate; bounds the queue and '
                         'enables deadline-aware shedding). 5 = the '
                         'survival trace')
    ap.add_argument('--devices', type=int, default=None,
                    help='shard the slot axis over a 1-D mesh of the '
                         'first N visible devices (simulate with '
                         'XLA_FLAGS=--xla_force_host_platform_device_'
                         'count=N)')
    ap.add_argument('--slots-per-device', type=int, default=None,
                    help='per-device slot budget on the mesh (overrides '
                         '--slots; the invariant elastic resizes keep)')
    ap.add_argument('--overlap-decode', default='auto',
                    choices=['auto', 'on', 'off'],
                    help='pipeline drained requests\' VAE decodes behind '
                         'the next denoise tick (auto: on when sharded)')
    ap.add_argument('--resize-to', type=int, default=None,
                    help='elastic-resize the mesh to this many devices '
                         'mid-replay (the drop/rejoin survival demo)')
    ap.add_argument('--resize-after', type=int, default=None,
                    help='completions before the mid-replay resize '
                         '(default: half the requests)')
    ap.add_argument('--cache-max-mb', type=float, default=None,
                    help='bound the persistent compilation cache; '
                         'least-recently-used executables are evicted')
    ap.add_argument('--log-level', default='info',
                    choices=['debug', 'info', 'warning', 'error'],
                    help='stdout logging verbosity')
    ap.add_argument('--trace', default=None, metavar='PATH',
                    help='record per-request tracing and write a Chrome/'
                         'Perfetto trace_event timeline here (diffusion '
                         'mode)')
    ap.add_argument('--log-json', default=None, metavar='PATH',
                    help='write the structured JSONL event log here '
                         '(diffusion mode; same events as --trace)')
    ap.add_argument('--prom', default=None, metavar='PATH',
                    help='write the final Prometheus text exposition of '
                         'the serving metrics here (diffusion mode)')
    ap.add_argument('--report-every', type=float, default=None,
                    metavar='SECONDS',
                    help='print an in-run metrics snapshot line every '
                         'this many seconds (diffusion mode)')
    args = ap.parse_args()
    setup_logging(args.log_level)
    if args.diffusion:
        precision = args.precision or ('w8a8' if args.w8a8 else 'fp32')
        serve_diffusion(args.img, args.steps, args.requests, args.rate,
                        args.slots, precision=precision, slo_ms=args.slo_ms,
                        quality_probe=args.quality_probe,
                        cache_interval=args.cache_interval,
                        exit_tol=args.exit_tol,
                        exit_patience=args.exit_patience,
                        cache_dir=args.cache_dir,
                        queue_depth=args.queue_depth,
                        shed_policy=args.shed_policy,
                        overload=args.overload,
                        devices=args.devices,
                        slots_per_device=args.slots_per_device,
                        overlap_decode=None if args.overlap_decode == 'auto'
                        else args.overlap_decode == 'on',
                        resize_to=args.resize_to,
                        resize_after=args.resize_after,
                        cache_max_mb=args.cache_max_mb,
                        trace_path=args.trace,
                        log_json_path=args.log_json,
                        prom_path=args.prom,
                        report_every=args.report_every)
        return
    cfg = smoke_config(args.arch) if args.preset == 'smoke' \
        else get(args.arch)
    mesh = make_mesh((1, 1), ('data', 'model'))
    seqs = serve_lm(cfg, mesh, args.batch, args.prompt, args.tokens,
                    quant=args.w8a8)
    log_serve.info('sample token ids: %s', np.asarray(seqs[0, :12]))


if __name__ == '__main__':
    main()
