"""Serving loop: batched LM decode (prefill + N decode steps) or diffusion
generation, with optional W8A8 (paper C1).

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --preset smoke --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get, smoke_config
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_mesh


def serve_lm(cfg, mesh, batch: int, prompt_len: int, new_tokens: int,
             quant: bool = False, dtype=jnp.float32):
    params = ST.init_params(jax.random.PRNGKey(0), cfg)
    max_len = prompt_len + new_tokens
    state = ST.init_serve_state(cfg, batch, max_len, cache_dtype=dtype)
    prefill = jax.jit(ST.build_prefill_step(cfg, dtype=dtype, quant=quant))
    decode = jax.jit(ST.build_decode_step(cfg, dtype=dtype, quant=quant),
                     donate_argnums=(1,))
    rng = np.random.default_rng(0)
    batch_in = {'tokens': jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == 'encdec':
        batch_in['frames'] = jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), dtype)
    with mesh:
        t0 = time.perf_counter()
        tok, state = prefill(params, state, batch_in)
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0
        out = [tok]
        t0 = time.perf_counter()
        for i in range(new_tokens - 1):
            tok, state = decode(params, state, tok,
                                jnp.int32(prompt_len + i))
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    tps = batch * (new_tokens - 1) / max(t_decode, 1e-9)
    print(f'[serve] prefill {prompt_len} toks x{batch}: {t_prefill:.3f}s; '
          f'decode {new_tokens-1} steps: {t_decode:.3f}s '
          f'({tps:.1f} tok/s)')
    return seqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='internlm2-1.8b')
    ap.add_argument('--preset', default='smoke', choices=['smoke', 'full'])
    ap.add_argument('--batch', type=int, default=2)
    ap.add_argument('--prompt', type=int, default=16)
    ap.add_argument('--tokens', type=int, default=16)
    ap.add_argument('--w8a8', action='store_true')
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.preset == 'smoke' \
        else get(args.arch)
    mesh = make_mesh((1, 1), ('data', 'model'))
    seqs = serve_lm(cfg, mesh, args.batch, args.prompt, args.tokens,
                    quant=args.w8a8)
    print('[serve] sample token ids:', np.asarray(seqs[0, :12]))


if __name__ == '__main__':
    main()
