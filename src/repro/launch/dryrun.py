"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder device count before any jax import (jax locks the
device count at first init).  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all

Outputs one JSON per cell under results/dryrun/ with memory analysis, cost
analysis, and the parsed collective traffic — the roofline inputs.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_cells
from repro.configs.registry import ARCHS, REAL_VOCABS, get
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig, init_adamw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), '..', '..', '..',
                           'results', 'dryrun')

# --- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link

_DTYPE_BYTES = {'f64': 8, 'f32': 4, 'bf16': 2, 'f16': 2, 's64': 8,
                'u64': 8, 's32': 4, 'u32': 4, 's16': 2, 'u16': 2,
                's8': 1, 'u8': 1, 'pred': 1, 'c64': 8, 'c128': 16}

_COLL_RE = re.compile(
    r'=\s*((?:\([^)]*\)|\S+))\s+'
    r'(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)'
    r'(?:-start)?\(')
_SHAPE_RE = re.compile(r'(\w+)\[([\d,]*)\]')


def _tensor_bytes(typestr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum per-partition output bytes of every collective op, with ring-cost
    weighting (all-reduce moves ~2x, others ~1x the payload)."""
    per_kind: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        out_type, kind = m.group(1), m.group(2)
        b = _tensor_bytes(out_type)
        per_kind[kind] = per_kind.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    weights = {'all-gather': 1.0, 'all-reduce': 2.0, 'reduce-scatter': 1.0,
               'all-to-all': 1.0, 'collective-permute': 1.0}
    weighted = sum(per_kind.get(k, 0.0) * w for k, w in weights.items())
    return {'bytes_per_kind': per_kind, 'count_per_kind': count,
            'weighted_bytes': weighted}


def _bf16_params(struct):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype in (jnp.float32,) else s.dtype),
        struct)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                serve_params_bf16: bool = True,
                opt_cfg: Optional['AdamWConfig'] = None,
                serve_quant: bool = False,
                mla_cache_seq: bool = False):
    """ShapeDtypeStruct stand-ins + shardings for one cell.
    Returns (fn, args tuple, in_shardings tuple, donate_argnums)."""
    params_struct = jax.eval_shape(
        lambda: ST.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = SH.param_pspecs(params_struct, mesh,
                              model_axis_tp=cfg.model_axis_tp)
    B, S = shape.global_batch, shape.seq_len
    real_vocab = REAL_VOCABS.get(cfg.name.replace('-smoke', ''), None)

    if shape.kind == 'train':
        import functools as _ft
        if opt_cfg is None:
            # >100B archs default to bf16 optimizer moments (§Perf fixes)
            big = cfg.name.split('-smoke')[0] in (
                'mistral-large-123b', 'jamba-1.5-large-398b')
            opt_cfg = AdamWConfig(
                moment_dtype='bfloat16' if big else 'float32')
        opt_struct = jax.eval_shape(
            _ft.partial(init_adamw,
                        moment_dtype=jnp.dtype(opt_cfg.moment_dtype)),
            params_struct)
        # AdamWState(step, m, v): m/v mirror the param tree, step is scalar
        from jax.sharding import PartitionSpec as P
        _pp = _ft.partial(SH.param_pspecs, mesh=mesh,
                          model_axis_tp=cfg.model_axis_tp)
        o_specs = type(opt_struct)(P(), _pp(opt_struct.m),
                                   _pp(opt_struct.v))
        batch_struct = ST.make_batch_struct(cfg, shape)
        b_specs = {k: SH.batch_pspecs(mesh, B, v.ndim)
                   for k, v in batch_struct.items()}
        fn = ST.build_train_step(cfg, opt_cfg, real_vocab)
        return (fn, (params_struct, opt_struct, batch_struct),
                (p_specs, o_specs, b_specs), (0, 1))

    if serve_quant:
        from repro.core.quantization import quantize_params
        params_struct = jax.eval_shape(quantize_params, params_struct)
    elif serve_params_bf16:
        params_struct = _bf16_params(params_struct)
    if serve_quant:
        p_specs = SH.param_pspecs(params_struct, mesh,
                                  model_axis_tp=cfg.model_axis_tp)
    state_struct = jax.eval_shape(
        lambda: ST.init_serve_state(cfg, B, S))
    c_specs = SH.cache_pspecs(state_struct, mesh, B,
                              mla_cache_seq=mla_cache_seq)
    from jax.sharding import PartitionSpec as P
    if cfg.family == 'encdec':
        c_specs['memory'] = P(SH.dp_spec(mesh, B), None, None)
    if shape.kind == 'prefill':
        batch_struct = ST.make_batch_struct(cfg, shape)
        batch_struct.pop('labels')
        b_specs = {k: SH.batch_pspecs(mesh, B, v.ndim)
                   for k, v in batch_struct.items()}
        fn = ST.build_prefill_step(cfg, quant=serve_quant)
        return (fn, (params_struct, state_struct, batch_struct),
                (p_specs, c_specs, b_specs), (1,))
    # decode
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    t_spec = SH.batch_pspecs(mesh, B, 2)
    fn = ST.build_decode_step(cfg, quant=serve_quant)
    return (fn, (params_struct, state_struct, token, pos),
            (p_specs, c_specs, t_spec, P()), (1,))


def _compile_cell(cfg, shape, mesh, **kw):
    fn, args, in_specs, donate = input_specs(cfg, shape, mesh, **kw)
    shardings = tuple(SH.named(mesh, s) for s in in_specs)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return a per-device list of dicts, newer ones a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _scan_units(cfg: ArchConfig) -> int:
    if cfg.family == 'encdec':
        return 1
    from repro.models.transformer import _block_kinds
    return len(_block_kinds(cfg))


def cost_probe(cfg: ArchConfig, shape: ShapeConfig, mesh,
               **kw) -> Dict[str, Any]:
    """XLA cost analysis counts a while (scan) body once, ignoring the trip
    count — so flops/bytes/collectives are probed on UNROLLED depth-U and
    depth-2U models and extrapolated linearly to the full depth (exact:
    every per-layer cost is affine in depth)."""
    import dataclasses as dc
    U = _scan_units(cfg)
    vals = []
    for mult in (1, 2):
        if cfg.family == 'encdec':
            pc = dc.replace(cfg, n_layers=mult, n_enc_layers=mult,
                            unroll_layers=True)
            steps_full = cfg.n_layers
        else:
            pc = dc.replace(cfg, n_layers=U * mult, unroll_layers=True)
            steps_full = cfg.n_layers // U
        compiled = _compile_cell(pc, shape, mesh, **kw)
        cost = _cost_dict(compiled)
        coll = parse_collectives(compiled.as_text())
        vals.append((float(cost.get('flops', 0.0)),
                     float(cost.get('bytes accessed', 0.0)),
                     float(coll['weighted_bytes'])))
    (f1, b1, c1), (f2, b2, c2) = vals
    k = steps_full - 1
    return {
        'scan_units': U, 'steps_full': steps_full,
        'flops_per_device': f1 + (f2 - f1) * k,
        'bytes_accessed_per_device': b1 + (b2 - b1) * k,
        'collective_bytes_per_device': c1 + (c2 - c1) * k,
        'probe_raw': {'depth_1U': vals[0], 'depth_2U': vals[1]},
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             mesh=None, out_dir: Optional[str] = None,
             with_probe: bool = True,
             cfg: Optional[ArchConfig] = None, **kw) -> Dict[str, Any]:
    cfg = cfg or get(arch_name)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh, **kw)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    probe = (cost_probe(cfg, shape, mesh, **kw) if with_probe else {
        'flops_per_device': float(cost.get('flops', 0.0)),
        'bytes_accessed_per_device': float(cost.get('bytes accessed', 0.0)),
        'collective_bytes_per_device': float(coll['weighted_bytes'])})
    flops = probe['flops_per_device']
    bytes_accessed = probe['bytes_accessed_per_device']
    coll_bytes = probe['collective_bytes_per_device']
    result = {
        'arch': arch_name, 'shape': shape_name,
        'mesh': dict(mesh.shape), 'devices': n_dev,
        'compile_s': round(t_compile, 1),
        'memory': {
            'argument_bytes': int(getattr(mem, 'argument_size_in_bytes', 0)),
            'output_bytes': int(getattr(mem, 'output_size_in_bytes', 0)),
            # newer jaxlib drops peak_memory_in_bytes; approximate with
            # args + outputs + temporaries + generated code
            'peak_bytes_per_device': int(
                getattr(mem, 'peak_memory_in_bytes', 0) or
                (getattr(mem, 'argument_size_in_bytes', 0) +
                 getattr(mem, 'output_size_in_bytes', 0) +
                 getattr(mem, 'temp_size_in_bytes', 0) +
                 getattr(mem, 'generated_code_size_in_bytes', 0))),
        },
        'cost': probe,
        'collectives_scanned_body': coll,
        'roofline': {
            'compute_s': flops / PEAK_FLOPS_BF16,
            'memory_s': bytes_accessed / HBM_BW,
            'collective_s': coll_bytes / ICI_BW,
        },
    }
    r = result['roofline']
    result['roofline']['dominant'] = max(
        ('compute_s', 'memory_s', 'collective_s'), key=lambda k: r[k])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = 'multipod' if multi_pod else 'singlepod'
        path = os.path.join(out_dir,
                            f'{arch_name}__{shape_name}__{tag}.json')
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    return result


def cells_for(arch_name: str):
    return [s.name for s in shape_cells(get(arch_name))]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='all')
    ap.add_argument('--shape', default='all')
    ap.add_argument('--mesh', default='both',
                    choices=['single', 'multi', 'both'])
    ap.add_argument('--out', default=os.path.abspath(RESULTS_DIR))
    ap.add_argument('--skip-existing', action='store_true')
    args = ap.parse_args()
    archs = sorted(ARCHS) if args.arch == 'all' else args.arch.split(',')
    meshes = {'single': [False], 'multi': [True],
              'both': [False, True]}[args.mesh]
    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = 'multipod' if multi else 'singlepod'
        for a in archs:
            shapes = (cells_for(a) if args.shape == 'all'
                      else args.shape.split(','))
            for s in shapes:
                if s not in cells_for(a):
                    print(f'SKIP {a} x {s} ({tag}): cell not live '
                          '(full-attention arch, see DESIGN.md)')
                    continue
                path = os.path.join(args.out, f'{a}__{s}__{tag}.json')
                if args.skip_existing and os.path.exists(path):
                    print(f'skip existing {a} x {s} ({tag})')
                    continue
                print(f'=== {a} x {s} ({tag}) ===', flush=True)
                try:
                    r = run_cell(a, s, multi, mesh=mesh, out_dir=args.out)
                    print(f'    ok: compile={r["compile_s"]}s '
                          f'peak/dev={r["memory"]["peak_bytes_per_device"]/2**30:.2f}GiB '
                          f'dominant={r["roofline"]["dominant"]}', flush=True)
                except Exception as e:
                    failures.append((a, s, tag, repr(e)))
                    traceback.print_exc()
    if failures:
        print('\nFAILURES:')
        for f in failures:
            print(' ', f)
        raise SystemExit(1)
    print('\nALL CELLS PASSED')


if __name__ == '__main__':
    main()
