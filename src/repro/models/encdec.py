"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d) directly.  The encoder is
a bidirectional transformer; the decoder is causal with cross-attention into
the encoder memory.  Cross-attention uses the paper's Eq. 6 reordering when
profitable (decode: 1 query vs T_enc memory — exactly its winning regime).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import attention, init_attention, \
    init_attention_cache


def _sinusoid(T: int, d: int) -> jax.Array:
    pos = jnp.arange(T)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_enc_block(key, cfg: ArchConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        'attn_norm': L.init_layernorm(cfg.d_model),
        'attn': init_attention(k1, cfg),
        'ffn_norm': L.init_layernorm(cfg.d_model),
        'mlp': L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False, bias=True),
    }


def init_dec_block(key, cfg: ArchConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        'attn_norm': L.init_layernorm(cfg.d_model),
        'attn': init_attention(k1, cfg),
        'xattn_norm': L.init_layernorm(cfg.d_model),
        'xattn': init_attention(k2, cfg),
        'ffn_norm': L.init_layernorm(cfg.d_model),
        'mlp': L.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False, bias=True),
    }


def init_encdec(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(
        jax.random.split(ks[0], n_enc))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        'enc_blocks': enc,
        'enc_norm': L.init_layernorm(cfg.d_model),
        'embed': L.init_embedding(ks[2], cfg.vocab, cfg.d_model),
        'dec_blocks': dec,
        'dec_norm': L.init_layernorm(cfg.d_model),
    }


def encode(p, cfg: ArchConfig, frames: jax.Array,
           dtype=jnp.float32) -> jax.Array:
    """frames (B, T_enc, d) stub embeddings -> memory (B, T_enc, d)."""
    x = frames.astype(dtype) + _sinusoid(frames.shape[1],
                                         cfg.d_model).astype(dtype)

    def body(h, blk):
        a, _ = attention(blk['attn'], cfg,
                         L.layernorm(blk['attn_norm'], h), causal=False)
        h = h + a
        h = h + L.mlp(blk['mlp'], L.layernorm(blk['ffn_norm'], h), act='gelu')
        return h, None

    if cfg.remat != 'none':
        body = jax.checkpoint(body)
    if cfg.unroll_layers:
        n = cfg.n_enc_layers or cfg.n_layers
        for i in range(n):
            x, _ = body(x, jax.tree_util.tree_map(
                lambda a: a[i], p['enc_blocks']))
    else:
        x, _ = jax.lax.scan(body, x, p['enc_blocks'])
    return L.layernorm(p['enc_norm'], x)


def _dec_scan(p, cfg: ArchConfig, x, memory, *, cache=None, cache_pos=None):
    def body(carry, inp):
        h = carry
        blk, blk_cache = inp
        a, nc = attention(blk['attn'], cfg,
                          L.layernorm(blk['attn_norm'], h),
                          cache=blk_cache, cache_pos=cache_pos)
        h = h + a
        xa, _ = attention(blk['xattn'], cfg,
                          L.layernorm(blk['xattn_norm'], h), memory=memory)
        h = h + xa
        h = h + L.mlp(blk['mlp'], L.layernorm(blk['ffn_norm'], h), act='gelu')
        return h, nc

    if cfg.remat != 'none':
        body = jax.checkpoint(body)
    if cfg.unroll_layers:
        at = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
        new_caches = []
        for i in range(cfg.n_layers):
            x, nc = body(x, (at(p['dec_blocks'], i),
                             None if cache is None else at(cache, i)))
            new_caches.append(nc)
        if cache is None:
            return x, None
        return x, jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *new_caches)
    if cache is None:
        x, _ = jax.lax.scan(lambda c, b: body(c, (b, None)), x,
                            p['dec_blocks'])
        return x, None
    return jax.lax.scan(body, x, (p['dec_blocks'], cache))


def decode_train(p, cfg: ArchConfig, frames: jax.Array, tokens: jax.Array,
                 dtype=jnp.float32) -> jax.Array:
    """Teacher-forced decoder logits (B, S, vocab)."""
    memory = encode(p, cfg, frames, dtype)
    B, S = tokens.shape
    x = L.embedding(p['embed'], tokens, dtype) + \
        _sinusoid(S, cfg.d_model).astype(dtype)
    x, _ = _dec_scan(p, cfg, x, memory)
    x = L.layernorm(p['dec_norm'], x)
    return L.embedding_logits(p['embed'], x)


def encdec_loss(p, cfg: ArchConfig, frames, tokens, labels,
                dtype=jnp.float32, real_vocab=None) -> jax.Array:
    logits = decode_train(p, cfg, frames, tokens, dtype).astype(jnp.float32)
    if real_vocab is not None and real_vocab < cfg.vocab:
        logits = jnp.where(jnp.arange(cfg.vocab) < real_vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init_dec_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    caches = [init_attention_cache(cfg, batch, max_len, dtype)
              for _ in range(cfg.n_layers)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)


def encdec_prefill(p, cfg: ArchConfig, frames, tokens, cache,
                   dtype=jnp.bfloat16):
    memory = encode(p, cfg, frames, dtype)
    B, S = tokens.shape
    x = L.embedding(p['embed'], tokens, dtype) + \
        _sinusoid(S, cfg.d_model).astype(dtype)
    x, cache = _dec_scan(p, cfg, x, memory, cache=cache,
                         cache_pos=jnp.int32(0))
    x = L.layernorm(p['dec_norm'], x[:, -1:])
    return L.embedding_logits(p['embed'], x), cache, memory


def encdec_decode(p, cfg: ArchConfig, token, cache, pos_scalar, memory,
                  dtype=jnp.bfloat16):
    x = L.embedding(p['embed'], token, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        _sinusoid(cfg.max_seq_len if cfg.max_seq_len < (1 << 20) else
                  1 << 16, cfg.d_model), pos_scalar, 1, 0).astype(dtype)
    x, cache = _dec_scan(p, cfg, x, memory, cache=cache,
                         cache_pos=pos_scalar)
    x = L.layernorm(p['dec_norm'], x)
    return L.embedding_logits(p['embed'], x), cache
