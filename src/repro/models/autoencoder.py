"""Latent VAE for LDM / SDM (encoder for training, decoder at sampling).

Downsample factor f = 2^(len(ch_mults)-1).  KL-regularized bottleneck as in
LDM; only the decoder sits on the serving path (latents -> pixels after the
denoising loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class VAEConfig:
    img_size: int
    in_ch: int = 3
    z_ch: int = 4
    base_ch: int = 128
    ch_mults: Tuple[int, ...] = (1, 2, 4, 4)
    groups: int = 32


def _res(key, c_in, c_out):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {'gn1': L.init_groupnorm(c_in),
         'conv1': L.init_conv(k1, 3, 3, c_in, c_out),
         'gn2': L.init_groupnorm(c_out),
         'conv2': L.init_conv(k2, 3, 3, c_out, c_out)}
    if c_in != c_out:
        p['skip'] = L.init_conv(k3, 1, 1, c_in, c_out)
    return p


def _res_apply(p, x, g):
    h = L.conv2d(p['conv1'], L.swish(L.groupnorm(p['gn1'], x, g)))
    h = L.conv2d(p['conv2'], L.swish(L.groupnorm(p['gn2'], h, g)))
    return (L.conv2d(p['skip'], x) if 'skip' in p else x) + h


def init_vae(key, cfg: VAEConfig) -> Dict[str, Any]:
    it = iter(jax.random.split(key, 256))
    enc, ch = [], cfg.base_ch
    p = {'enc_in': L.init_conv(next(it), 3, 3, cfg.in_ch, cfg.base_ch)}
    for lvl, m in enumerate(cfg.ch_mults):
        out = cfg.base_ch * m
        lvl_p = {'res': _res(next(it), ch, out)}
        ch = out
        if lvl < len(cfg.ch_mults) - 1:
            lvl_p['down'] = L.init_conv(next(it), 3, 3, ch, ch)
        enc.append(lvl_p)
    p['enc'] = enc
    p['enc_out'] = L.init_conv(next(it), 3, 3, ch, 2 * cfg.z_ch)
    p['dec_in'] = L.init_conv(next(it), 3, 3, cfg.z_ch, ch)
    dec = []
    for lvl, m in reversed(list(enumerate(cfg.ch_mults))):
        out = cfg.base_ch * m
        lvl_p = {'res': _res(next(it), ch, out)}
        ch = out
        if lvl > 0:
            lvl_p['up'] = L.init_conv(next(it), 4, 4, ch, ch)
        dec.append(lvl_p)
    p['dec'] = dec
    p['dec_gn'] = L.init_groupnorm(ch)
    p['dec_out'] = L.init_conv(next(it), 3, 3, ch, cfg.in_ch)
    return p


def vae_encode(p, cfg: VAEConfig, x: jax.Array, key=None):
    """x (B, H, W, 3) -> latent (B, H/f, W/f, z_ch) (mean if key is None)."""
    g = cfg.groups
    h = L.conv2d(p['enc_in'], x)
    for lvl_p in p['enc']:
        h = _res_apply(lvl_p['res'], h, g)
        if 'down' in lvl_p:
            h = L.conv2d(lvl_p['down'], h, stride=2)
    moments = L.conv2d(p['enc_out'], h)
    mean, logvar = jnp.split(moments, 2, axis=-1)
    if key is None:
        return mean
    return mean + jnp.exp(0.5 * jnp.clip(logvar, -30, 20)) * \
        jax.random.normal(key, mean.shape, mean.dtype)


def vae_decode(p, cfg: VAEConfig, z: jax.Array) -> jax.Array:
    g = cfg.groups
    h = L.conv2d(p['dec_in'], z)
    for lvl_p in p['dec']:
        h = _res_apply(lvl_p['res'], h, g)
        if 'up' in lvl_p:
            h = L.conv_transpose2d(lvl_p['up'], h, stride=2)  # C4 path
    h = L.swish(L.groupnorm(p['dec_gn'], h, g))
    return jnp.tanh(L.conv2d(p['dec_out'], h))
