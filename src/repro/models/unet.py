"""Diffusion UNet (DDPM / LDM / SDM families — paper Table I).

Encoder/decoder ResBlocks with GroupNorm+swish (fused kernel, C5), MHA
blocks with the LSE softmax (C2), optional cross-attention (SDM text
conditioning), and stride-2 transposed-conv upsampling routed through the
sparsity-aware dataflow (C4).  A w8a8 ``PrecisionPolicy`` (see
``repro.core.precision``) runs every attention projection through the
W8A8 path (C1), optionally with analog-noise injection — the serving
configurations the paper evaluates.  The legacy ``quant=True`` flag is a
deprecated alias for ``policy=PrecisionPolicy.w8a8()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import NEG_INF
from repro.core.lse_softmax import lse_softmax


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    img_size: int
    in_ch: int
    base_ch: int
    ch_mults: Tuple[int, ...]
    n_res_blocks: int
    attn_resolutions: Tuple[int, ...]
    n_heads: int = 8
    context_dim: Optional[int] = None      # cross-attention (SDM)
    transformer_depth: int = 1
    timesteps: int = 1000
    latent: bool = False                    # operates in VAE latent space
    sparse_dataflow: bool = True            # C4 toggle
    groups: int = 32


def timestep_embedding(t: jax.Array, dim: int) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_resblock(key, c_in: int, c_out: int, t_dim: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {
        'gn1': L.init_groupnorm(c_in),
        'conv1': L.init_conv(ks[0], 3, 3, c_in, c_out),
        't_proj': L.init_linear(ks[1], t_dim, c_out),
        'gn2': L.init_groupnorm(c_out),
        'conv2': L.init_conv(ks[2], 3, 3, c_out, c_out),
    }
    if c_in != c_out:
        p['skip'] = L.init_conv(ks[3], 1, 1, c_in, c_out)
    return p


def _gn_swish(gn_p, x, groups):
    from repro.kernels import ops as kops
    return kops.fused_gn_swish(x, gn_p['scale'], gn_p['bias'], groups=groups)


def resblock(p, x: jax.Array, t_emb: jax.Array, groups: int) -> jax.Array:
    h = _gn_swish(p['gn1'], x, groups)
    h = L.conv2d(p['conv1'], h)
    h = h + L.linear(p['t_proj'], L.swish(t_emb))[:, None, None, :]
    h = _gn_swish(p['gn2'], h, groups)
    h = L.conv2d(p['conv2'], h)
    skip = L.conv2d(p['skip'], x) if 'skip' in p else x
    return skip + h


def init_attn_block(key, ch: int, n_heads: int,
                    context_dim: Optional[int]) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p = {
        'gn': L.init_groupnorm(ch),
        'wq': L.init_linear(ks[0], ch, ch, bias=False),
        'wk': L.init_linear(ks[1], ch, ch, bias=False),
        'wv': L.init_linear(ks[2], ch, ch, bias=False),
        'wo': L.init_linear(ks[3], ch, ch),
    }
    if context_dim is not None:
        p.update({
            'xq': L.init_linear(ks[4], ch, ch, bias=False),
            'xk': L.init_linear(ks[5], context_dim, ch, bias=False),
            'xv': L.init_linear(ks[6], context_dim, ch, bias=False),
            'xo': L.init_linear(ks[7], ch, ch),
        })
    return p


def _mha(q, k, v, n_heads: int, quant_proj=None) -> jax.Array:
    """q (B, S, C), k/v (B, T, C) -> (B, S, C) via LSE softmax (C2)."""
    B, S, C = q.shape
    T = k.shape[1]
    hd = C // n_heads
    qh = q.reshape(B, S, n_heads, hd).astype(jnp.float32) * hd ** -0.5
    kh = k.reshape(B, T, n_heads, hd).astype(jnp.float32)
    vh = v.reshape(B, T, n_heads, hd).astype(jnp.float32)
    s = jnp.einsum('bshd,bthd->bhst', qh, kh)
    pr = lse_softmax(s, axis=-1)
    o = jnp.einsum('bhst,bthd->bshd', pr, vh)
    return o.reshape(B, S, C).astype(q.dtype)


def attn_block(p, x: jax.Array, groups: int, n_heads: int,
               context: Optional[jax.Array] = None,
               policy=None, keys=None) -> jax.Array:
    """``policy`` selects the matmul precision for every projection (a
    PrecisionPolicy; the legacy positional bool still resolves).  ``keys``
    is a NoiseKeyStream dispensing one key per projection when the policy
    injects analog noise — without one, a per-block stream anchored at the
    policy's seed is used."""
    from repro.core.precision import resolve, stream_for
    pol = resolve(policy)
    if keys is None:
        keys = stream_for(pol)
    B, H, W, C = x.shape
    h = L.groupnorm(p['gn'], x, groups)
    t = h.reshape(B, H * W, C)

    def proj(q, v):
        return L.linear(q, v, policy=pol, noise_key=keys.next())

    o = _mha(proj(p['wq'], t), proj(p['wk'], t), proj(p['wv'], t), n_heads)
    t = t + proj(p['wo'], o)
    if context is not None and 'xq' in p:
        o = _mha(proj(p['xq'], t), proj(p['xk'], context),
                 proj(p['xv'], context), n_heads)
        t = t + proj(p['xo'], o)
    return x + t.reshape(B, H, W, C)


# ---------------------------------------------------------------------------
# UNet
# ---------------------------------------------------------------------------

def init_unet(key, cfg: UNetConfig) -> Dict[str, Any]:
    t_dim = cfg.base_ch * 4
    it = iter(jax.random.split(key, 1024))
    p: Dict[str, Any] = {
        't_mlp1': L.init_linear(next(it), cfg.base_ch, t_dim),
        't_mlp2': L.init_linear(next(it), t_dim, t_dim),
        'conv_in': L.init_conv(next(it), 3, 3, cfg.in_ch, cfg.base_ch),
    }
    chs = [cfg.base_ch]
    ch = cfg.base_ch
    res = cfg.img_size
    down = []
    for lvl, mult in enumerate(cfg.ch_mults):
        out_ch = cfg.base_ch * mult
        blocks = []
        for _ in range(cfg.n_res_blocks):
            b = {'res': init_resblock(next(it), ch, out_ch, t_dim)}
            ch = out_ch
            if res in cfg.attn_resolutions:
                b['attn'] = init_attn_block(next(it), ch, cfg.n_heads,
                                            cfg.context_dim)
            blocks.append(b)
            chs.append(ch)
        lvl_p = {'blocks': blocks}
        if lvl < len(cfg.ch_mults) - 1:
            lvl_p['down'] = L.init_conv(next(it), 3, 3, ch, ch)
            chs.append(ch)
            res //= 2
        down.append(lvl_p)
    p['down'] = down
    p['mid'] = {
        'res1': init_resblock(next(it), ch, ch, t_dim),
        'attn': init_attn_block(next(it), ch, cfg.n_heads, cfg.context_dim),
        'res2': init_resblock(next(it), ch, ch, t_dim),
    }
    up = []
    for lvl, mult in reversed(list(enumerate(cfg.ch_mults))):
        out_ch = cfg.base_ch * mult
        blocks = []
        for _ in range(cfg.n_res_blocks + 1):
            b = {'res': init_resblock(next(it), ch + chs.pop(), out_ch,
                                      t_dim)}
            ch = out_ch
            if res in cfg.attn_resolutions:
                b['attn'] = init_attn_block(next(it), ch, cfg.n_heads,
                                            cfg.context_dim)
            blocks.append(b)
        lvl_p = {'blocks': blocks}
        if lvl > 0:
            # stride-2 transposed conv -> C4 sparse dataflow target
            lvl_p['upconv'] = L.init_conv(next(it), 4, 4, ch, ch)
            res *= 2
        up.append(lvl_p)
    p['up'] = up
    p['gn_out'] = L.init_groupnorm(ch)
    p['conv_out'] = L.init_conv(next(it), 3, 3, ch, cfg.in_ch)
    return p


def unet_apply(p, cfg: UNetConfig, x: jax.Array, t: jax.Array,
               context: Optional[jax.Array] = None,
               quant: bool = False, *, policy=None,
               noise_key=None) -> jax.Array:
    """x (B, H, W, C_in), t (B,) int timesteps -> predicted noise.

    ``policy`` is the PrecisionPolicy for every attention projection
    (fp32 / w8a8 / w8a8+noise); ``quant=True`` is its deprecated boolean
    ancestor.  A noisy policy draws one independent perturbation per
    projection from ``noise_key`` (default: the policy's seed anchor),
    so the whole forward is deterministic under a fixed key.
    """
    from repro.core.precision import resolve, stream_for
    pol = resolve(policy, quant)
    keys = stream_for(pol, noise_key)
    g = cfg.groups
    t_emb = timestep_embedding(t, cfg.base_ch)
    t_emb = L.linear(p['t_mlp2'], L.swish(L.linear(p['t_mlp1'], t_emb)))
    h = L.conv2d(p['conv_in'], x)
    skips = [h]
    for lvl, lvl_p in enumerate(p['down']):
        for b in lvl_p['blocks']:
            h = resblock(b['res'], h, t_emb, g)
            if 'attn' in b:
                h = attn_block(b['attn'], h, g, cfg.n_heads, context, pol, keys)
            skips.append(h)
        if 'down' in lvl_p:
            h = L.conv2d(lvl_p['down'], h, stride=2)
            skips.append(h)
    h = resblock(p['mid']['res1'], h, t_emb, g)
    h = attn_block(p['mid']['attn'], h, g, cfg.n_heads, context, pol, keys)
    h = resblock(p['mid']['res2'], h, t_emb, g)
    for lvl_p in p['up']:
        for b in lvl_p['blocks']:
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = resblock(b['res'], h, t_emb, g)
            if 'attn' in b:
                h = attn_block(b['attn'], h, g, cfg.n_heads, context, pol, keys)
        if 'upconv' in lvl_p:
            h = L.conv_transpose2d(lvl_p['upconv'], h, stride=2,
                                   sparse_dataflow=cfg.sparse_dataflow)
    h = _gn_swish(p['gn_out'], h, g)
    return L.conv2d(p['conv_out'], h)
