"""Mamba2 (SSD — state-space duality) mixer layer.

Chunked SSD: within a chunk the recurrence is computed as a (masked,
decay-weighted) attention-like quadratic form; across chunks a small state
(B, H, P, N) is carried by ``lax.scan`` — giving O(L) sequence scaling, which
is what makes the ``long_500k`` cell runnable for this family.

Decode is the pure recurrence: state' = state * exp(dt*A) + dt * (B outer x).

The paper's attention-specific techniques (C2/C3) do not apply here
(attention-free family — see DESIGN.md §4); C1 (W8A8) applies to the in/out
projections.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return s, d_inner, n_heads


def init_mamba(key, cfg: ArchConfig) -> Dict[str, Any]:
    s, d_inner, H = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 5)
    return {
        # input projections kept separate (z / xBC / dt) so each output dim
        # shards evenly over the tensor axis (a fused 2*d_inner+2GN+H dim
        # is not divisible by the mesh)
        'in_z': L.init_linear(ks[0], cfg.d_model, d_inner, bias=False),
        'in_xbc': L.init_linear(ks[3], cfg.d_model, conv_dim, bias=False),
        'in_dt': L.init_linear(ks[4], cfg.d_model, H, bias=False),
        'conv_w': L.normal_init(ks[1], (s.d_conv, conv_dim), 0.02),
        'conv_b': jnp.zeros((conv_dim,), jnp.float32),
        'A_log': jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        'D': jnp.ones((H,), jnp.float32),
        'dt_bias': jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, H).astype(jnp.float32))),
        'norm': L.init_rmsnorm(d_inner),
        'out_proj': L.init_linear(ks[2], d_inner, cfg.d_model, bias=False),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xBC (B, S, C), w (K, C).
    ``state`` (B, K-1, C) carries the last K-1 inputs for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)             # (B, S+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu(out + b), new_state


def _ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, chunk: int,
                 init_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """SSD over chunks.
    x  (B, S, H, P)   dt (B, S, H)   A (H,) (negative)
    Bm (B, S, G, N)   Cm (B, S, G, N);  H = G*rep.
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = x.shape[1] // Q
    # chunk views: (B, nC, Q, ...) -> scan over nC
    xc = x.reshape(B, nC, Q, H, P)
    dtc = dt.reshape(B, nC, Q, H)
    Bc = Bm.reshape(B, nC, Q, G, N)
    Cc = Cm.reshape(B, nC, Q, G, N)
    dA = dtc * A                                          # (B, nC, Q, H) <= 0
    cum = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    state0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def body(state, inp):
        xq, dtq, bq, cq, dAq, cumq = inp                 # leading dim B
        # decay from chunk start to position i: exp(cum_i)
        # intra-chunk: attention-like with decay mask
        #   L[i,j] = exp(cum_i - cum_j) * (j <= i)
        li = cumq[:, :, None, :]                          # (B,Q,1,H)
        lj = cumq[:, None, :, :]                          # (B,1,Q,H)
        mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        Lm = jnp.where(mask[None, :, :, None],
                       jnp.exp(jnp.clip(li - lj, -60.0, 0.0)), 0.0)
        # scores: C_i . B_j  per group -> (B, Q, Q, G)
        s = jnp.einsum('bign,bjgn->bijg', cq, bq)
        s = s[..., :, None].repeat(rep, axis=-1).reshape(B, Q, Q, H) * Lm
        y_intra = jnp.einsum('bijh,bjh,bjhp->bihp', s, dtq, xq)
        # inter-chunk: y += C_i . state * exp(cum_i)
        cqh = cq[:, :, :, None, :].repeat(rep, axis=3).reshape(B, Q, H, N)
        decay_i = jnp.exp(jnp.clip(cumq, -60.0, 0.0))     # (B,Q,H)
        y_inter = jnp.einsum('bihn,bhpn,bih->bihp', cqh, state, decay_i)
        # state update: state' = state*exp(cum_end) + sum_j exp(cum_end-cum_j) dt_j B_j x_j
        cum_end = cumq[:, -1, :]                          # (B,H)
        decay_out = jnp.exp(jnp.clip(cum_end[:, None, :] - cumq, -60.0, 0.0))
        bqh = bq[:, :, :, None, :].repeat(rep, axis=3).reshape(B, Q, H, N)
        new_state = state * jnp.exp(jnp.clip(cum_end, -60.0, 0.0)
                                    )[:, :, None, None] + \
            jnp.einsum('bjh,bjhn,bjhp->bhpn', dtq * decay_out, bqh, xq)
        return new_state, y_intra + y_inter

    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in
                   (xc, dtc, Bc, Cc, dA.reshape(B, nC, Q, H),
                    cum))
    final_state, ys = jax.lax.scan(body, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nC * Q, H, P)[:, :S]
    return y, final_state


def mamba(p: Dict[str, Any], cfg: ArchConfig, x: jax.Array, *,
          cache: Optional[Dict[str, jax.Array]] = None,
          quant: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """x (B, S, d).  cache = {'conv': (B, K-1, conv_dim),
    'state': (B, H, P, N)} for decode (S == 1) / chunk-streamed prefill."""
    s, d_inner, H = _dims(cfg)
    B, S, d = x.shape
    G, N, P = s.n_groups, s.d_state, s.headdim
    from repro.distributed.sharding import shard_hint
    tp = 'model' if cfg.model_axis_tp else None
    x = shard_hint(x, 'dp', None, None)
    z = shard_hint(L.linear(p['in_z'], x, quant=quant), 'dp', None, tp)
    xBC = shard_hint(L.linear(p['in_xbc'], x, quant=quant), 'dp', None, tp)
    dt = L.linear(p['in_dt'], x, quant=quant)
    conv_state = None if cache is None else cache['conv']
    xBC, new_conv = _causal_conv(xBC, p['conv_w'].astype(xBC.dtype),
                                 p['conv_b'].astype(xBC.dtype), conv_state)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xh = xs.reshape(B, S, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, S, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, S, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p['dt_bias'])  # (B,S,H)
    A = -jnp.exp(p['A_log'])                              # (H,) negative

    if cache is not None and S == 1:
        # pure recurrence (decode)
        dA = jnp.exp(dt[:, 0] * A)                        # (B,H)
        rep = H // G
        bqh = Bm[:, 0, :, None, :].repeat(rep, 2).reshape(B, H, N)
        cqh = Cm[:, 0, :, None, :].repeat(rep, 2).reshape(B, H, N)
        state = cache['state'].astype(jnp.float32)
        state = state * dA[:, :, None, None] + \
            jnp.einsum('bh,bhn,bhp->bhpn', dt[:, 0], bqh, xh[:, 0])
        y = jnp.einsum('bhn,bhpn->bhp', cqh, state)[:, None]  # (B,1,H,P)
        final_state = state
    else:
        init_state = None if cache is None else cache['state']
        y, final_state = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, init_state)
    y = y + xh * p['D'][:, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = L.rmsnorm(p['norm'], y * jax.nn.silu(z))
    out = L.linear(p['out_proj'], y, quant=quant)
    new_cache = None
    if cache is not None:
        new_cache = {'conv': new_conv.astype(cache['conv'].dtype),
                     'state': final_state.astype(cache['state'].dtype)}
    return out, new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    s, d_inner, H = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        'conv': jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        'state': jnp.zeros((batch, H, s.headdim, s.d_state), dtype),
    }
