"""Multi-head attention: GQA (+RoPE / M-RoPE), MLA, KV caches, decode.

Paper hooks:
  * C2 — softmax always goes through the LSE decomposition
    (``repro.core.lse_softmax`` semantics; the Pallas flash kernel on TPU,
    grouped-einsum + ``lse_softmax`` under XLA).
  * C3 — scale folding: 1/sqrt(d_k) is folded into the query projection
    output (free); the (Q W_K^T) X^T reordering is available for
    cross-attention via ``repro.core.attention_decomp``.
  * C1 — ``quant=True`` routes projections through the W8A8 path.

Sharding notes: KV heads are logically replicated ``cfg.kv_repeat`` times so
the head axis shards evenly over the tensor axis (DESIGN.md §4); the grouped
einsum keeps K/V un-repeated per group, so no HBM duplication of the cache
beyond the sharding replicas.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.lse_softmax import lse_softmax
from repro.models import layers as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, hd), pos (B, S) -> rotated x (half-split convention)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs     # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, pos3: jax.Array, theta: float,
          sections: Tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): pos3 (B, S, 3) are (t, h, w) position ids;
    frequency channels are partitioned into ``sections`` (sum = hd/2), each
    section rotated by its own position stream.  For pure text all three
    streams are equal and M-RoPE == RoPE."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    # build per-channel position: (B, S, hd/2)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=hd // 2)
    pos_c = jnp.take_along_axis(
        pos3.astype(jnp.float32),
        jnp.broadcast_to(sec_id, pos3.shape[:2] + (hd // 2,)),
        axis=-1)                                          # (B, S, hd/2)
    ang = pos_c * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_rope(cfg: ArchConfig, x: jax.Array, pos: jax.Array) -> jax.Array:
    if cfg.rope == 'none':
        return x
    if cfg.rope == 'mrope':
        if pos.ndim == 2:  # text-only: broadcast to 3 streams
            pos = jnp.broadcast_to(pos[..., None], pos.shape + (3,))
        return mrope(x, pos, cfg.rope_theta, cfg.mrope_sections)
    return rope(x, pos, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Grouped-query attention core (no KV-head materialization)
# ---------------------------------------------------------------------------

def gqa_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
             causal: bool, q_offset: jax.Array | int = 0,
             kv_len: Optional[jax.Array] = None,
             scale: float | None = None) -> jax.Array:
    """q (B, S, H, hd), k/v (B, T, Hkv, hd) with H = G*rep, Hkv = G.
    Grouped einsum: K/V are never repeated in memory.
    ``kv_len``: number of valid cache rows (decode); ``q_offset``: absolute
    position of q row 0 (causal masking against the cache)."""
    B, S, H, hd = q.shape
    T, G = k.shape[1], k.shape[2]
    rep = H // G
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, S, G, rep, hd).astype(jnp.float32) * scale
    s = jnp.einsum('bsgrd,btgd->bgrst', qg, k.astype(jnp.float32))
    t_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        q_pos = jnp.arange(S) + q_offset
        mask = mask & (t_pos[None, :] <= q_pos[:, None])
    if kv_len is not None:
        mask = mask & (t_pos[None, :] < kv_len)
    s = jnp.where(mask, s, NEG_INF)
    p = lse_softmax(s, axis=-1)                           # paper Eq. 4
    out = jnp.einsum('bgrst,btgd->bsgrd', p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def flash_core(q, k, v, *, causal):
    """TPU Pallas path (inference / prefill).  Repeats KV heads (cheap vs
    the S*T score matrix) and calls the flash kernel."""
    from repro.kernels import ops as kops
    B, S, H, hd = q.shape
    G = k.shape[2]
    kr = jnp.repeat(k, H // G, axis=2)
    vr = jnp.repeat(v, H // G, axis=2)
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
        vr.transpose(0, 2, 1, 3), causal=causal)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads * cfg.kv_repeat
    ks = jax.random.split(key, 4)
    return {
        'wq': L.init_linear(ks[0], d, H * hd, bias=cfg.attn_bias),
        'wk': L.init_linear(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.attn_bias),
        'wv': L.init_linear(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.attn_bias),
        'wo': L.init_linear(ks[3], H * hd, d, bias=cfg.attn_bias),
    }


def _project_kv(p, cfg: ArchConfig, x_kv: jax.Array, pos: Optional[jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
    from repro.distributed.sharding import shard_hint
    B, T, _ = x_kv.shape
    hd = cfg.hd
    k = L.linear(p['wk'], x_kv).reshape(B, T, cfg.n_kv_heads, hd)
    v = L.linear(p['wv'], x_kv).reshape(B, T, cfg.n_kv_heads, hd)
    if pos is not None:
        k = apply_rope(cfg, k, pos)
    if cfg.kv_repeat > 1:  # logical replication for even TP sharding
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    tp = 'model' if cfg.model_axis_tp else None
    k = shard_hint(k, 'dp', None, tp, None)
    v = shard_hint(v, 'dp', None, tp, None)
    return k, v


def attention(p: Dict[str, Any], cfg: ArchConfig, x: jax.Array, *,
              pos: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              cache: Optional[Dict[str, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              causal: bool = True,
              impl: str = 'xla',
              quant: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """One attention layer.

    modes:
      * train / no-cache forward:       cache=None
      * prefill (fills cache):          cache=empty dict of buffers, cache_pos=0
      * decode (1 token, reads cache):  cache=filled, cache_pos=current length
    ``memory`` switches to cross-attention (no cache, not causal).
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    hd, H = cfg.hd, cfg.n_heads
    from repro.distributed.sharding import shard_hint
    if pos is None:
        pos = jnp.arange(S)[None, :] + (0 if cache_pos is None else cache_pos)
        pos = jnp.broadcast_to(pos, (B, S))
    tp = 'model' if cfg.model_axis_tp else None
    x = shard_hint(x, 'dp', None, None)
    q = L.linear(p['wq'], x, quant=quant).reshape(B, S, H, hd)
    q = shard_hint(q, 'dp', None, tp, None)
    q = apply_rope(cfg, q, pos)

    if memory is not None:                       # cross-attention
        k, v = _project_kv(p, cfg, memory, None)
        out = gqa_core(q, k, v, causal=False)
        new_cache = cache
    elif cache is None:                          # plain causal self-attn
        k, v = _project_kv(p, cfg, x, pos)
        if impl == 'pallas':
            out = flash_core(q, k, v, causal=causal)
        else:
            out = gqa_core(q, k, v, causal=causal)
        new_cache = None
    else:                                        # prefill or decode
        k, v = _project_kv(p, cfg, x, pos)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache['k'], k.astype(cache['k'].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache['v'], v.astype(cache['v'].dtype), cache_pos, axis=1)
        new_cache = {'k': ck, 'v': cv}
        kv_len = cache_pos + S
        out = gqa_core(q, ck, cv, causal=True, q_offset=cache_pos,
                       kv_len=kv_len)
    from repro.distributed.sharding import shard_hint as _sh
    out = _sh(out, 'dp', None, 'model' if cfg.model_axis_tp else None, None)
    y = L.linear(p['wo'], out.reshape(B, S, H * hd), quant=quant)
    y = _sh(y, 'dp', None, None)
    return y, new_cache


def init_attention_cache(cfg: ArchConfig, batch: int, max_len: int,
                         dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    Hkv = cfg.n_kv_heads * cfg.kv_repeat
    shape = (batch, max_len, Hkv, cfg.hd)
    return {'k': jnp.zeros(shape, dtype), 'v': jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig) -> Dict[str, Any]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        'wq': L.init_linear(ks[0], d, H * qk_dim, bias=False),
        'w_dkv': L.init_linear(ks[1], d, m.kv_lora_rank, bias=False),
        'w_kpe': L.init_linear(ks[2], d, m.qk_rope_head_dim, bias=False),
        'w_uk': L.init_linear(ks[3], m.kv_lora_rank,
                              H * m.qk_nope_head_dim, bias=False),
        'w_uv': L.init_linear(ks[4], m.kv_lora_rank,
                              H * m.v_head_dim, bias=False),
        'wo': L.init_linear(ks[5], H * m.v_head_dim, d, bias=False),
        'kv_norm': L.init_rmsnorm(m.kv_lora_rank),
    }


def mla_attention(p, cfg: ArchConfig, x: jax.Array, *,
                  pos: Optional[jax.Array] = None,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  cache_pos: Optional[jax.Array] = None,
                  quant: bool = False,
                  impl: str = 'xla') -> Tuple[jax.Array, Optional[Dict]]:
    """MLA with compressed-KV cache.  Prefill/train uses the naive
    (decompress) path; decode uses the *absorbed* path (q projected into the
    latent space — the MLA analogue of paper Eq. 6 reordering), so the cache
    holds only (c_kv, k_pe)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rpe, vd, rank = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                           m.v_head_dim, m.kv_lora_rank)
    if pos is None:
        pos = jnp.arange(S)[None, :] + (0 if cache_pos is None else cache_pos)
        pos = jnp.broadcast_to(pos, (B, S))
    from repro.distributed.sharding import shard_hint
    tp = 'model' if cfg.model_axis_tp else None
    x = shard_hint(x, 'dp', None, None)
    q = L.linear(p['wq'], x, quant=quant).reshape(B, S, H, nope + rpe)
    q = shard_hint(q, 'dp', None, tp, None)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = rope(q_pe, pos, cfg.rope_theta)
    c_kv = L.rmsnorm(p['kv_norm'], L.linear(p['w_dkv'], x, quant=quant))
    k_pe = rope(L.linear(p['w_kpe'], x, quant=quant)[:, :, None, :],
                pos, cfg.rope_theta)[:, :, 0, :]          # (B, S, rpe)
    scale = (nope + rpe) ** -0.5

    decode = cache is not None and cache_pos is not None
    if decode:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache['c_kv'], c_kv.astype(cache['c_kv'].dtype), cache_pos, 1)
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache['k_pe'], k_pe.astype(cache['k_pe'].dtype), cache_pos, 1)
        new_cache = {'c_kv': cc, 'k_pe': cp}
        T = cc.shape[1]
        kv_len = cache_pos + S
        # absorbed path: q_nope' = q_nope @ W_uk^T  -> latent space
        from repro.core.quantization import QTensor as _QT
        _raw = lambda w: (w.dequantize(jnp.float32)
                          if isinstance(w, _QT) else w)
        w_uk = _raw(p['w_uk']['w']).reshape(rank, H, nope)
        q_lat = jnp.einsum('bshn,rhn->bshr', q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))      # (B,S,H,rank)
        s = (jnp.einsum('bshr,btr->bhst', q_lat,
                        cc.astype(jnp.float32)) +
             jnp.einsum('bshp,btp->bhst', q_pe.astype(jnp.float32),
                        cp.astype(jnp.float32))) * scale
        t_pos = jnp.arange(T)
        q_pos = jnp.arange(S) + cache_pos
        mask = (t_pos[None, :] <= q_pos[:, None]) & (t_pos[None, :] < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        pr = lse_softmax(s, axis=-1)
        o_lat = jnp.einsum('bhst,btr->bshr', pr, cc.astype(jnp.float32))
        w_uv = _raw(p['w_uv']['w']).reshape(rank, H, vd)
        out = jnp.einsum('bshr,rhv->bshv', o_lat, w_uv.astype(jnp.float32))
    else:
        new_cache = None
        k_nope = L.linear(p['w_uk'], c_kv).reshape(B, S, H, nope)
        vv = L.linear(p['w_uv'], c_kv).reshape(B, S, H, vd)
        s = (jnp.einsum('bshn,bthn->bhst', q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32)) +
             jnp.einsum('bshp,btp->bhst', q_pe.astype(jnp.float32),
                        k_pe.astype(jnp.float32))) * scale
        t_pos = jnp.arange(S)
        mask = t_pos[None, :] <= t_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        pr = lse_softmax(s, axis=-1)
        out = jnp.einsum('bhst,bthv->bshv', pr, vv.astype(jnp.float32))
    out = shard_hint(out.astype(x.dtype), 'dp', None, tp, None)
    y = L.linear(p['wo'], out.reshape(B, S, H * vd), quant=quant)
    return shard_hint(y, 'dp', None, None), new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {'c_kv': jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            'k_pe': jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}
