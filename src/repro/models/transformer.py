"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

Layers are scanned (stacked params, ``lax.scan``) so HLO size and compile
time are O(1) in depth — mandatory for the 88-layer dry-run cells — with a
configurable remat policy on the scan body.

Families:
  dense | moe | vlm : homogeneous [attn + (mlp|moe)] blocks
  ssm               : homogeneous [mamba] blocks (no separate FFN)
  hybrid            : scanned *super-blocks*; within a super-block the
                      (attention/mamba, dense/moe) pattern of
                      cfg.hybrid_block / cfg.hybrid_ffn is unrolled
                      (jamba: 1 attn : 7 mamba, MoE every other FFN)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.attention import (attention, init_attention,
                                    init_attention_cache, init_mla,
                                    init_mla_cache, mla_attention)

NORMS = {'rmsnorm': (L.init_rmsnorm, L.rmsnorm),
         'layernorm': (L.init_layernorm, L.layernorm)}


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_kinds(cfg: ArchConfig):
    """Per-sub-layer (mixer, ffn) kinds within one scanned unit."""
    if cfg.family == 'hybrid':
        return tuple(zip(cfg.hybrid_block, cfg.hybrid_ffn))
    if cfg.family == 'ssm':
        return (('M', '-'),)
    mixer = 'L' if cfg.mla is not None else 'A'
    ffn = 'E' if (cfg.moe is not None and cfg.moe.every == 1) else 'D'
    return ((mixer, ffn),)


def n_scan_steps(cfg: ArchConfig) -> int:
    return cfg.n_layers // len(_block_kinds(cfg))


def init_block(key, cfg: ArchConfig) -> Dict[str, Any]:
    """One scanned unit (possibly several sub-layers for hybrids)."""
    init_norm = NORMS[cfg.norm][0]
    p = {}
    ks = jax.random.split(key, len(_block_kinds(cfg)))
    for i, (mixer, ffn) in enumerate(_block_kinds(cfg)):
        k1, k2 = jax.random.split(ks[i])
        sub = {'mix_norm': init_norm(cfg.d_model)}
        if mixer == 'A':
            sub['attn'] = init_attention(k1, cfg)
        elif mixer == 'L':
            sub['attn'] = init_mla(k1, cfg)
        elif mixer == 'M':
            sub['mamba'] = SSM.init_mamba(k1, cfg)
        if ffn == 'D':
            sub['ffn_norm'] = init_norm(cfg.d_model)
            sub['mlp'] = L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                    gated=(cfg.act in ('swish', 'silu')),
                                    bias=cfg.mlp_bias)
        elif ffn == 'E':
            sub['ffn_norm'] = init_norm(cfg.d_model)
            sub['moe'] = MOE.init_moe(k2, cfg)
        p[f'sub{i}'] = sub
    return p


def apply_block(p, cfg: ArchConfig, x: jax.Array, *,
                cache: Optional[Dict] = None,
                cache_pos=None, pos=None,
                quant: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    norm = NORMS[cfg.norm][1]
    new_cache = {} if cache is not None else None
    for i, (mixer, ffn) in enumerate(_block_kinds(cfg)):
        sub = p[f'sub{i}']
        sub_cache = None if cache is None else cache[f'sub{i}']
        h = norm(sub['mix_norm'], x)
        if mixer == 'A':
            h, nc = attention(sub['attn'], cfg, h, pos=pos, cache=sub_cache,
                              cache_pos=cache_pos, quant=quant)
        elif mixer == 'L':
            h, nc = mla_attention(sub['attn'], cfg, h, pos=pos,
                                  cache=sub_cache, cache_pos=cache_pos,
                                  quant=quant)
        else:  # mamba
            h, nc = SSM.mamba(sub['mamba'], cfg, h, cache=sub_cache,
                              quant=quant)
        x = x + h
        if ffn != '-':
            h = norm(sub['ffn_norm'], x)
            if ffn == 'E':
                h = MOE.moe_ffn(sub['moe'], cfg, h, quant=quant)
            else:
                h = L.mlp(sub['mlp'], h, act=cfg.act, quant=quant,
                          tp_axis='model' if cfg.model_axis_tp else None)
            x = x + h
        if new_cache is not None:
            new_cache[f'sub{i}'] = nc if nc is not None else sub_cache
    return x, new_cache


def init_block_cache(cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    c = {}
    for i, (mixer, _) in enumerate(_block_kinds(cfg)):
        if mixer == 'A':
            c[f'sub{i}'] = init_attention_cache(cfg, batch, max_len, dtype)
        elif mixer == 'L':
            c[f'sub{i}'] = init_mla_cache(cfg, batch, max_len, dtype)
        else:
            c[f'sub{i}'] = SSM.init_mamba_cache(cfg, batch)
    return c


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    steps = n_scan_steps(cfg)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(
        jax.random.split(ks[0], steps))
    p = {
        'embed': L.init_embedding(ks[1], cfg.vocab, cfg.d_model),
        'blocks': blocks,
        'final_norm': NORMS[cfg.norm][0](cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p['lm_head'] = L.init_linear(ks[2], cfg.d_model, cfg.vocab,
                                     bias=False, stddev=0.02)
    return p


def _readout(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    from repro.distributed.sharding import shard_hint
    x = NORMS[cfg.norm][1](p['final_norm'], x)
    logits = (L.embedding_logits(p['embed'], x) if cfg.tie_embeddings
              else L.linear(p['lm_head'], x))
    return shard_hint(logits, 'dp', None, 'model')


def _scan_blocks(p, cfg: ArchConfig, x: jax.Array, *, cache=None,
                 cache_pos=None, pos=None, quant=False):
    """Scan the stacked blocks; cache (if any) is scanned in/out."""

    def body(carry, inp):
        h = carry
        blk, blk_cache = inp
        h, new_cache = apply_block(blk, cfg, h, cache=blk_cache,
                                   cache_pos=cache_pos, pos=pos, quant=quant)
        return h, new_cache

    if cfg.remat == 'full':
        body = jax.checkpoint(body)
    elif cfg.remat == 'dots':
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    if cfg.unroll_layers:     # dry-run cost probes (see ArchConfig)
        steps = n_scan_steps(cfg)
        at = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
        new_caches = []
        for i in range(steps):
            x, nc = body(x, (at(p['blocks'], i),
                             None if cache is None else at(cache, i)))
            new_caches.append(nc)
        if cache is None:
            return x, None
        return x, jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *new_caches)
    if cache is None:
        x, _ = jax.lax.scan(lambda c, b: body(c, (b, None)), x, p['blocks'])
        return x, None
    x, new_cache = jax.lax.scan(body, x, (p['blocks'], cache))
    return x, new_cache


def lm_apply(p, cfg: ArchConfig, tokens: jax.Array, *,
             dtype=jnp.float32, pos: Optional[jax.Array] = None,
             inputs_embeds: Optional[jax.Array] = None,
             quant: bool = False) -> jax.Array:
    """tokens (B, S) -> logits (B, S, vocab).  ``inputs_embeds`` overrides
    the embedding lookup (modality-frontend stubs)."""
    from repro.distributed.sharding import shard_hint
    x = (L.embedding(p['embed'], tokens, dtype) if inputs_embeds is None
         else inputs_embeds.astype(dtype))
    x = shard_hint(x, 'dp', None, None)
    x, _ = _scan_blocks(p, cfg, x, pos=pos, quant=quant)
    return _readout(p, cfg, x)


def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    steps = n_scan_steps(cfg)
    caches = [init_block_cache(cfg, batch, max_len, dtype)
              for _ in range(steps)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)


def lm_prefill(p, cfg: ArchConfig, tokens: jax.Array, cache, *,
               dtype=jnp.bfloat16, quant: bool = False):
    """Fill the cache with a prompt; returns (last-token logits, cache)."""
    x = L.embedding(p['embed'], tokens, dtype)
    x, cache = _scan_blocks(p, cfg, x, cache=cache,
                            cache_pos=jnp.int32(0), quant=quant)
    return _readout(p, cfg, x[:, -1:]), cache


def lm_decode(p, cfg: ArchConfig, token: jax.Array, cache,
              pos_scalar: jax.Array, *, dtype=jnp.bfloat16,
              quant: bool = False):
    """One decode step.  token (B, 1); pos_scalar = current length."""
    x = L.embedding(p['embed'], token, dtype)
    x, cache = _scan_blocks(p, cfg, x, cache=cache, cache_pos=pos_scalar,
                            quant=quant)
    return _readout(p, cfg, x), cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(p, cfg: ArchConfig, tokens: jax.Array, labels: jax.Array, *,
            dtype=jnp.float32, real_vocab: Optional[int] = None,
            inputs_embeds=None) -> jax.Array:
    """Causal cross-entropy; padded vocab rows masked.  labels == -1 ignored."""
    logits = lm_apply(p, cfg, tokens, dtype=dtype,
                      inputs_embeds=inputs_embeds).astype(jnp.float32)
    if real_vocab is not None and real_vocab < cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab) < real_vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
