"""Mixture-of-Experts: top-k router + capacity-based dispatch + grouped GEMM.

Shardability is the design driver (DESIGN.md §3): tokens are processed in
``G`` independent dispatch groups (sharded over the ``data`` axis) and the
expert dimension of the grouped GEMM shards over the ``model`` axis
(expert parallelism).  The scatter/gather between token layout and expert
layout is local per group; crossing the expert sharding is the all-to-all XLA
inserts — exactly the EP exchange of a 1000-node deployment.

Dispatch: for each token pick top-k experts; position within expert via a
stable argsort rank; tokens beyond per-group capacity C are dropped
(``.at[].add(mode='drop')``), matching GShard/Switch semantics with
capacity_factor ~= 1.25.  FLOPs are honest: E*C*d*ff with E*C ~= T*k*cf —
no dense all-experts fallback.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.core.quantization import QTensor
from repro.models import layers as L


def _wt(w, dtype):
    """Expert weight -> compute dtype (dequantizing serve-time QTensors)."""
    return w.dequantize(dtype) if isinstance(w, QTensor) else w.astype(dtype)


def init_moe(key, cfg: ArchConfig) -> Dict[str, Any]:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        'router': L.init_linear(ks[0], d, E, bias=False, stddev=0.02),
        'w_gate': L.normal_init(ks[1], (E, d, ff), 0.02),
        'w_up': L.normal_init(ks[2], (E, d, ff), 0.02),
        'w_down': L.normal_init(ks[3], (E, ff, d), 0.02),
    }
    if m.n_shared:
        p['shared'] = L.init_mlp(ks[4], d, m.n_shared * ff, gated=True,
                                 bias=False)
    return p


def _dispatch_indices(expert_ids: jax.Array, E: int, C: int):
    """expert_ids (T, k) -> flat slot index (T, k) into an (E*C,) buffer;
    slots >= E*C (drops) handled by mode='drop' at scatter.

    Rank within expert = stable-argsort trick: sort the flattened assignment
    list by expert id; a token's rank is its position minus the first
    position of its expert.
    """
    T, k = expert_ids.shape
    flat = expert_ids.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    # first occurrence index of each expert in the sorted list
    first = jnp.searchsorted(sorted_e, sorted_e, side='left')
    rank_sorted = jnp.arange(T * k) - first
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    slot = flat * C + rank                               # (T*k,)
    slot = jnp.where(rank < C, slot, E * C)              # overflow -> dropped
    return slot.reshape(T, k)


def _dispatch(x: jax.Array, p: Dict[str, Any], m: MoEConfig, C: int):
    """One dispatch group: x (T, d) -> (buf (E, C, d), slot (T, k),
    top_p (T, k)).  Called under vmap over G (the scatter is group-local)."""
    T, d = x.shape
    E, k = m.n_experts, m.top_k
    logits = L.linear(p['router'], x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)               # (T, k)
    if m.router_normalize:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    slot = _dispatch_indices(top_e, E, C)                # (T, k)
    # scatter tokens to expert buffers (slot >= E*C means dropped)
    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[slot.reshape(-1)].add(
        jnp.repeat(x, k, axis=0), mode='drop')
    return buf.reshape(E, C, d), slot, top_p


def _combine(y_buf: jax.Array, slot: jax.Array, top_p: jax.Array,
             dtype) -> jax.Array:
    """y_buf (E, C, d) -> (T, d), weighted by top_p (vmapped over G)."""
    E, C, d = y_buf.shape
    T, k = slot.shape
    y_tok = y_buf.reshape(E * C, d)[
        jnp.clip(slot.reshape(-1), 0, E * C - 1)]        # (T*k, d)
    valid = (slot.reshape(-1) < E * C)[:, None]
    y_tok = jnp.where(valid, y_tok, 0.0).reshape(T, k, d)
    return jnp.einsum('tkd,tk->td', y_tok, top_p.astype(dtype))


def moe_ffn(p: Dict[str, Any], cfg: ArchConfig, x: jax.Array,
            quant: bool = False) -> jax.Array:
    """x (B, S, d) -> (B, S, d)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = min(cfg.moe_groups, T)
    while T % G:
        G -= 1
    Tg = T // G
    C = max(1, int(Tg * m.top_k * m.capacity_factor / m.n_experts))
    # round capacity to a lane-friendly multiple
    C = -(-C // 8) * 8
    from repro.distributed.sharding import shard_hint
    xg = shard_hint(x.reshape(G, Tg, d), 'dp', None, None)
    act = L.ACTIVATIONS[cfg.act]
    # dispatch (group-local scatter) under vmap, then EXPLICIT-G expert
    # GEMMs so the (G, E, C, ...) buffers can be sharding-constrained:
    # G over the DP axes, E over 'model' (expert parallelism).  Without
    # the constraints XLA replicates the dispatch buffer across the model
    # axis (~68 GB/layer/device measured on deepseek — EXPERIMENTS §Perf).
    buf, slot, top_p = jax.vmap(lambda t: _dispatch(t, p, m, C))(xg)
    buf = shard_hint(buf, 'dp', 'model', None, None)      # (G, E, C, d)
    h = act(jnp.einsum('gecd,edf->gecf', buf, _wt(p['w_gate'], x.dtype))) \
        * jnp.einsum('gecd,edf->gecf', buf, _wt(p['w_up'], x.dtype))
    h = shard_hint(h, 'dp', 'model', None, None)          # (G, E, C, ff)
    y_buf = jnp.einsum('gecf,efd->gecd', h, _wt(p['w_down'], x.dtype))
    y_buf = shard_hint(y_buf, 'dp', 'model', None, None)
    y = jax.vmap(lambda a, b, c: _combine(a, b, c, x.dtype))(
        y_buf, slot, top_p)
    y = shard_hint(y, 'dp', None, None).reshape(B, S, d)
    if 'shared' in p:
        y = y + L.mlp(p['shared'], x, act=cfg.act, quant=quant,
                      tp_axis='model' if cfg.model_axis_tp else None)
    return y


def router_aux_loss(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean over groups)."""
    m = cfg.moe
    logits = L.linear(p['router'], x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)                   # (B, S, E)
    top_e = jnp.argmax(probs, -1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return m.n_experts * jnp.sum(frac_tokens * frac_probs)
