"""Foundational pure-JAX layers.

Conventions (framework-wide):
  * params are plain nested dicts of jnp arrays (pytrees) — pjit-friendly;
  * every layer is an ``init_*`` (returns params) + ``apply`` function pair;
  * parameters are stored fp32 ("master"); compute dtype is configurable
    (bf16 by default at scale) — casting happens at use;
  * 2-D weights are (in, out); conv kernels are HWIO; activations NHWC / BSD.

Quantized (W8A8) inference paths mirror the DiffLight MR-bank datapath: see
``repro.core.quantization`` and ``repro.kernels.w8a8_matmul``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, quantize, quantize_per_channel

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _fan_in_init(key, shape, fan_in, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, *, bias: bool = True,
                stddev: Optional[float] = None) -> Params:
    kw, kb = jax.random.split(key)
    w = (normal_init(kw, (d_in, d_out), stddev) if stddev is not None
         else _fan_in_init(kw, (d_in, d_out), d_in))
    p = {'w': w}
    if bias:
        p['b'] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p: Params, x: jax.Array, *, dtype=None, quant: bool = False,
           policy=None, noise_key=None) -> jax.Array:
    """y = x @ w + b, executed per the precision policy.

    ``policy`` (a ``repro.core.precision.PrecisionPolicy`` or name)
    selects fp32 / W8A8 (DiffLight C1) / W8A8 with analog noise; a noisy
    policy draws perturbations from ``noise_key`` (falling back to the
    policy's ``noise_seed`` anchor).  ``quant=True`` is the deprecated
    boolean form of ``policy=PrecisionPolicy.w8a8()``.
    """
    from repro.core.precision import resolve
    pol = resolve(policy, quant)
    dtype = dtype or x.dtype
    w = p['w']
    if pol.quantized or isinstance(w, QTensor):
        if pol.noisy:
            from repro.core.photonic.noise import noisy_w8a8_matmul
            key = noise_key if noise_key is not None else \
                jax.random.PRNGKey(pol.noise_seed)
            y = noisy_w8a8_matmul(key, x, w, model=pol.noise,
                                  n_channels=pol.n_channels).astype(dtype)
        else:
            from repro.kernels import ops as kops
            y = kops.w8a8_matmul(x, w).astype(dtype)
    else:
        # bf16 compute keeps bf16 HBM layout (MXU accumulates f32
        # internally); only f32 compute asks for an f32 accumulator output.
        acc = jnp.float32 if dtype == jnp.float32 else dtype
        y = jax.lax.dot_general(
            x.astype(dtype), w.astype(dtype),
            dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=acc).astype(dtype)
    if 'b' in p:
        y = y + p['b'].astype(dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, stddev: float = 0.02) -> Params:
    return {'table': normal_init(key, (vocab, d), stddev)}


def embedding(p: Params, ids: jax.Array, dtype=jnp.float32) -> jax.Array:
    # one-hot matmul shards better than gather on TPU for TP'd vocab
    return jnp.take(p['table'], ids, axis=0).astype(dtype)


def embedding_logits(p: Params, x: jax.Array, dtype=None) -> jax.Array:
    """Tied readout: x @ table^T."""
    dtype = dtype or x.dtype
    return jax.lax.dot_general(
        x, p['table'].astype(dtype).T,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_layernorm(d: int) -> Params:
    return {'scale': jnp.ones((d,), jnp.float32),
            'bias': jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p['scale'] + p['bias']).astype(x.dtype)


def init_rmsnorm(d: int) -> Params:
    return {'scale': jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p['scale']).astype(x.dtype)


def init_groupnorm(channels: int) -> Params:
    return {'scale': jnp.ones((channels,), jnp.float32),
            'bias': jnp.zeros((channels,), jnp.float32)}


def groupnorm(p: Params, x: jax.Array, groups: int = 32,
              eps: float = 1e-5) -> jax.Array:
    """GroupNorm over NHWC (normalizes within channel groups; the paper's
    broadband-MR normalization block)."""
    N, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xf = x.astype(jnp.float32).reshape(N, H, W, g, C // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(N, H, W, C)
    return (y * p['scale'] + p['bias']).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def swish(x: jax.Array) -> jax.Array:
    """f(x) = x * sigmoid(x) — paper Eq. 5 (SOA sigmoid + MR product)."""
    return x * jax.nn.sigmoid(x)


silu = swish


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {'swish': swish, 'silu': swish, 'gelu': gelu,
               'relu': jax.nn.relu}


# ---------------------------------------------------------------------------
# Conv (NHWC, HWIO)
# ---------------------------------------------------------------------------

def init_conv(key, kh: int, kw: int, c_in: int, c_out: int, *,
              bias: bool = True) -> Params:
    p = {'w': _fan_in_init(key, (kh, kw, c_in, c_out), kh * kw * c_in)}
    if bias:
        p['b'] = jnp.zeros((c_out,), jnp.float32)
    return p


def conv2d(p: Params, x: jax.Array, stride: int = 1,
           padding: str = 'SAME') -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, p['w'].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'),
        preferred_element_type=jnp.float32).astype(x.dtype)
    if 'b' in p:
        y = y + p['b'].astype(x.dtype)
    return y


def conv_transpose2d(p: Params, x: jax.Array, stride: int = 2, *,
                     sparse_dataflow: bool = True) -> jax.Array:
    """Transposed conv; ``sparse_dataflow=True`` uses the zero-skipping
    sub-pixel decomposition (paper §IV-C)."""
    from repro.core import sparse_dataflow as sd
    f = sd.conv_transpose_sparse if sparse_dataflow else sd.conv_transpose_dense
    y = f(x, p['w'].astype(x.dtype), stride)
    if 'b' in p:
        y = y + p['b'].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, gated: bool = True,
             bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {'up': init_linear(ks[0], d, d_ff, bias=bias),
         'down': init_linear(ks[1], d_ff, d, bias=bias)}
    if gated:
        p['gate'] = init_linear(ks[2], d, d_ff, bias=bias)
    return p


def mlp(p: Params, x: jax.Array, act: str = 'swish',
        quant: bool = False, tp_axis: str | None = 'model') -> jax.Array:
    from repro.distributed.sharding import shard_hint
    f = ACTIVATIONS[act]
    up = linear(p['up'], x, quant=quant)
    up = shard_hint(up, *(('dp',) + (None,) * (up.ndim - 2) + (tp_axis,)))
    if 'gate' in p:
        h = f(linear(p['gate'], x, quant=quant)) * up
    else:
        h = f(up)
    return linear(p['down'], h, quant=quant)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int) -> int:
    return -(-vocab // multiple) * multiple


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, 'size'))
