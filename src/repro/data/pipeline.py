"""Deterministic, indexable synthetic data pipelines.

Design for fault tolerance: every batch is a pure function of
(seed, step) — a restarted or re-meshed job can resume at any step with no
pipeline state to restore, and straggler hosts can be dropped without
reshuffling (stateless skip-ahead).  Each host materializes only its own
shard of the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def token_batch(cfg: TokenPipelineConfig, step: int,
                shard: Tuple[int, int] = (0, 1)) -> dict:
    """Batch for `step`, host-shard `shard=(index, count)`.
    Synthetic but *learnable* stream: each sequence is an arithmetic token
    progression with noise, so training loss decreases measurably."""
    idx, count = shard
    assert cfg.global_batch % count == 0
    local = cfg.global_batch // count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, idx]))
    start = rng.integers(0, cfg.vocab, (local, 1))
    stride = rng.integers(1, 7, (local, 1))
    seq = (start + stride * np.arange(cfg.seq_len + 1)) % cfg.vocab
    noise_mask = rng.random((local, cfg.seq_len + 1)) < 0.05
    noise = rng.integers(0, cfg.vocab, (local, cfg.seq_len + 1))
    seq = np.where(noise_mask, noise, seq).astype(np.int32)
    return {'tokens': jnp.asarray(seq[:, :-1]),
            'labels': jnp.asarray(seq[:, 1:])}


def token_stream(cfg: TokenPipelineConfig, start_step: int = 0,
                 shard: Tuple[int, int] = (0, 1)) -> Iterator[dict]:
    step = start_step
    while True:
        yield token_batch(cfg, step, shard)
        step += 1


@dataclasses.dataclass(frozen=True)
class ImagePipelineConfig:
    img_size: int
    channels: int
    global_batch: int
    seed: int = 0


def image_batch(cfg: ImagePipelineConfig, step: int,
                shard: Tuple[int, int] = (0, 1)) -> jax.Array:
    """Synthetic image batch in [-1, 1]: smooth random fields (so a DDPM can
    actually fit structure, unlike white noise)."""
    idx, count = shard
    local = cfg.global_batch // count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, idx, 7]))
    low = rng.normal(size=(local, 4, 4, cfg.channels)).astype(np.float32)
    img = jax.image.resize(jnp.asarray(low),
                           (local, cfg.img_size, cfg.img_size, cfg.channels),
                           method='bicubic')
    return jnp.tanh(img)
