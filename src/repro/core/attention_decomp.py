"""Attention MatMul decomposition (paper Eq. 6) and scale folding.

DiffLight computes  Q.K^T = Q.(X.W_K)^T = (Q.W_K^T).X^T  so the photonic
banks never materialize K, and folds the 1/sqrt(d_k) scaling into the weight
matrix so no separate scaling pass is needed.

On TPU the same rewrite is a compute-reordering choice:

  standard:   K = X W_K        (T x d x d_k MACs), then Q K^T (S x T x d_k)
  reordered:  Q' = Q W_K^T     (S x d_k x d MACs), then Q' X^T (S x T x d)

FLOPs(standard)  = T*d*d_k + S*T*d_k
FLOPs(reordered) = S*d_k*d + S*T*d
The reordering wins when S*d_k*d + S*T*d < T*d*d_k + S*T*d_k, i.e. roughly
when S << T and d_k < d (cross-attention / decode with short queries).  We
expose both paths and a cost-based chooser.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fold_scale_into_wq(w_q: jax.Array, d_k: int) -> jax.Array:
    """Fold 1/sqrt(d_k) into the query projection (always free)."""
    return w_q * (d_k ** -0.5)


def scores_standard(q: jax.Array, x_kv: jax.Array, w_k: jax.Array):
    """q (..., S, d_k) already projected+scaled; x_kv (..., T, d)."""
    k = jnp.einsum('...td,dk->...tk', x_kv, w_k)
    return jnp.einsum('...sk,...tk->...st', q, k)


def scores_reordered(q: jax.Array, x_kv: jax.Array, w_k: jax.Array):
    """Eq. 6: (Q W_K^T) X^T — K is never materialized."""
    q_prime = jnp.einsum('...sk,dk->...sd', q, w_k)
    return jnp.einsum('...sd,...td->...st', q_prime, x_kv)


def decomp_flops(S: int, T: int, d: int, d_k: int) -> tuple[int, int]:
    standard = T * d * d_k + S * T * d_k
    reordered = S * d_k * d + S * T * d
    return standard, reordered


def scores_auto(q: jax.Array, x_kv: jax.Array, w_k: jax.Array):
    """Pick the cheaper path by static FLOP count (shapes are static under
    jit, so this resolves at trace time)."""
    S, d_k = q.shape[-2], q.shape[-1]
    T, d = x_kv.shape[-2], x_kv.shape[-1]
    std, reo = decomp_flops(S, T, d, d_k)
    return scores_reordered(q, x_kv, w_k) if reo < std else \
        scores_standard(q, x_kv, w_k)
