"""DiffLight architectural configuration (paper §IV, Figure 3).

[Y, N, K, H, L, M]:
  Y — conv/norm blocks in the Residual unit
  K x N — MR bank array dims of each conv block (K rows, N columns)
  H — attention head blocks in the MHA unit
  M x L — MR bank array dims in each attention head (and linear block)

Paper DSE optimum: [4, 12, 3, 6, 6, 3].
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Tuple

from repro.core.photonic import devices as dev


@dataclasses.dataclass(frozen=True)
class DiffLightConfig:
    Y: int = 4
    N: int = 12
    K: int = 3
    H: int = 6
    L: int = 6
    M: int = 3
    # scheduling / dataflow toggles (paper §IV-C)
    sparse_dataflow: bool = True
    pipelined: bool = True
    dac_sharing: bool = True
    # replication factor: how many DiffLight tiles operate in parallel
    tiles: int = 1

    # -- derived geometry ----------------------------------------------------
    @property
    def conv_macs_per_pass(self) -> int:
        """Residual unit: Y blocks, each K rows x N wavelengths."""
        return self.Y * self.K * self.N

    @property
    def head_score_macs_per_pass(self) -> int:
        """Attention head: upper path (4 MR banks, M x L)."""
        return self.M * self.L

    @property
    def head_v_macs_per_pass(self) -> int:
        """Attention head: V path (2 MR banks, M x N)."""
        return self.M * self.N

    @property
    def mha_macs_per_pass(self) -> int:
        return self.H * (self.head_score_macs_per_pass
                         + self.head_v_macs_per_pass)

    @property
    def linear_macs_per_pass(self) -> int:
        """Linear+add block: M x L array."""
        return self.M * self.L

    def mrs_per_waveguide(self) -> int:
        """Wavelengths per waveguide = columns (bounded by WDM limit)."""
        return max(self.N, self.L)

    def dacs_residual(self) -> int:
        """DACs in the Residual unit (2 banks per block, K*N MRs each)."""
        per_block = 2 * self.K * self.N
        return self.Y * per_block

    def dacs_mha(self) -> int:
        """7 MR banks per head (paper Fig. 6) + 2 in linear block."""
        per_head = 4 * self.M * self.L + 3 * self.M * self.N
        return self.H * per_head + 2 * self.M * self.L

    def validate(self):
        assert self.mrs_per_waveguide() <= dev.MAX_MRS_PER_WAVEGUIDE
        return self


PAPER_OPTIMUM = DiffLightConfig()          # [4,12,3,6,6,3]
BASELINE = DiffLightConfig(sparse_dataflow=False, pipelined=False,
                           dac_sharing=False)


def dse_space(max_mrs: int = dev.MAX_MRS_PER_WAVEGUIDE
              ) -> Iterator[DiffLightConfig]:
    """The design space swept in §V (component counts under the WDM limit)."""
    for Y, N, K, H, L, M in itertools.product(
            (2, 4, 6, 8), (8, 12, 16, 24, 36), (2, 3, 4, 6),
            (4, 6, 8, 12), (4, 6, 8, 12), (2, 3, 4, 6)):
        if max(N, L) <= max_mrs:
            yield DiffLightConfig(Y=Y, N=N, K=K, H=H, L=L, M=M)
