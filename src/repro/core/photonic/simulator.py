"""DiffLight performance/energy simulator (paper §V methodology).

Maps a UNet workload onto the DiffLight units and integrates device
activity using Table II latencies/powers:

  Residual unit  (Y blocks, KxN banks)  <- conv + transposed-conv MACs
  MHA unit       (H heads, 4 MxL + 3 MxN banks) <- Q/K/V proj, scores, attn.V
  Linear+Add     (MxL banks)            <- out-proj / time-emb MACs
  ECU            (comparator/subtractor/LUT)  <- softmax elements (Eq. 4)
  SOA blocks     <- swish activations

Pass model (one MR-bank result cycle):
  stages: imprint (DAC) -> emit (VCSEL) -> propagate -> detect (BPD)
          -> digitize (ADC)
  baseline  : t_pass = sum(stage latencies)          (no overlap)
  pipelined : t_pass = max(stage latencies)          (stage-level overlap)
  DAC sharing (2 columns / DAC set): imprint stage runs twice; under
  pipelining it stays hidden beneath the ADC stage, in baseline it adds
  t_DAC — matching the paper's "more tuning time, large energy saving".
  Inter-unit pipelining: with `pipelined`, Residual / MHA / Linear units
  overlap (latency = max over units); baseline serializes them.

Energy per pass: every DAC holds its analog value for the whole pass;
VCSELs emit for the optical flight window scaled by the loss-budget laser
factor; PDs/ADCs burn their own stage; weight-bank EO retunes amortize over
``weight_reuse`` passes.  The ECU softmax energy is per score element.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.photonic import devices as dev
from repro.core.photonic.arch import DiffLightConfig
from repro.core.photonic.workload import Workload

WEIGHT_REUSE = 64      # passes a weight tile stays resident (output tiling)


@dataclasses.dataclass
class SimReport:
    name: str
    latency_s: float
    energy_j: float
    ops: float                       # nominal (dense) ops
    unit_latency: Dict[str, float]
    unit_energy: Dict[str, float]

    @property
    def gops(self) -> float:
        return self.ops / self.latency_s / 1e9

    @property
    def epb_pj(self) -> float:
        """Energy-per-bit, pJ (8-bit operands, 2 operands per MAC)."""
        bits = 8.0 * self.ops
        return self.energy_j / bits * 1e12


def _pass_times(cfg: DiffLightConfig):
    t_prop = dev.propagation_delay()
    imprint = dev.DAC_8B.latency * (2 if cfg.dac_sharing else 1)
    stages = [imprint, dev.VCSEL.latency, t_prop,
              dev.PHOTODETECTOR.latency, dev.ADC_8B.latency]
    t_seq = sum(stages)
    t_pipe = max(stages)
    return (t_pipe if cfg.pipelined else t_seq), t_prop


def _bank_pass_energy(n_rows: int, n_cols: int, n_banks: int, t_pass: float,
                      cfg: DiffLightConfig, laser_factor: float) -> float:
    """Energy of one pass through one block built from `n_banks` MR bank
    arrays of (n_rows x n_cols)."""
    n_mrs = n_banks * n_rows * n_cols
    n_dacs = n_mrs / (2 if cfg.dac_sharing else 1)
    e_dac = n_dacs * dev.DAC_8B.power * t_pass
    t_optical = (dev.VCSEL.latency + dev.propagation_delay()
                 + dev.PHOTODETECTOR.latency)
    e_vcsel = n_cols * dev.VCSEL.power * laser_factor * t_optical
    e_pd = 2 * n_rows * dev.PHOTODETECTOR.power * dev.PHOTODETECTOR.latency
    e_adc = n_rows * dev.ADC_8B.power * dev.ADC_8B.latency
    # weight-bank EO retuning amortized over reuse
    e_tune = (n_mrs / 2) * dev.EO_TUNING.power * dev.EO_TUNING.latency \
        / WEIGHT_REUSE
    return e_dac + e_vcsel + e_pd + e_adc + e_tune


ECU_SOFTMAX_E_PER_ELEM = (
    dev.COMPARATOR.power * dev.COMPARATOR.latency +
    dev.SUBTRACTOR.power * dev.SUBTRACTOR.latency +
    2 * dev.LUT.power * dev.LUT.latency)          # max-track, sub, exp+ln

ECU_SOFTMAX_T_PER_ELEM = (dev.COMPARATOR.latency + dev.SUBTRACTOR.latency +
                          2 * dev.LUT.latency)

SOA_E_PER_ELEM = (dev.SOA.power * dev.SOA.latency +
                  dev.VCSEL.power * dev.VCSEL.latency +
                  dev.PHOTODETECTOR.power * dev.PHOTODETECTOR.latency)


def simulate(w: Workload, cfg: DiffLightConfig,
             name: str | None = None) -> SimReport:
    cfg.validate()
    t_pass, _ = _pass_times(cfg)
    laser = dev.laser_power_factor(cfg.mrs_per_waveguide())

    # --- unit workloads (MACs) ---
    convt = w.convt_macs * (1.0 - w.convt_zero_frac
                            if cfg.sparse_dataflow else 1.0)
    residual_macs = w.conv_macs + convt
    mha_macs = w.proj_macs + w.attn_score_macs + w.attn_v_macs
    linear_macs = w.linear_macs

    # --- throughput per pass (MACs) ---
    res_rate = cfg.conv_macs_per_pass * cfg.tiles
    mha_rate = cfg.mha_macs_per_pass * cfg.tiles
    lin_rate = cfg.linear_macs_per_pass * cfg.tiles

    res_passes = residual_macs / res_rate
    mha_passes = mha_macs / mha_rate
    lin_passes = linear_macs / lin_rate

    t_res = res_passes * t_pass
    t_mha = mha_passes * t_pass
    t_lin = lin_passes * t_pass
    # ECU softmax: pipelined -> concurrent with score generation (hidden);
    # baseline -> serialized behind the MHA unit, H elements in parallel
    t_ecu = 0.0 if cfg.pipelined else \
        w.softmax_elems / cfg.H * ECU_SOFTMAX_T_PER_ELEM

    if cfg.pipelined:            # inter-unit overlap
        latency = max(t_res, t_mha, t_lin)
    else:
        latency = t_res + t_mha + t_lin + t_ecu

    # --- energy ---
    e_res = res_passes * cfg.Y * _bank_pass_energy(
        cfg.K, cfg.N, 2, t_pass, cfg, laser)
    e_mha = mha_passes * cfg.H * (
        _bank_pass_energy(cfg.M, cfg.L, 4, t_pass, cfg, laser) +
        _bank_pass_energy(cfg.M, cfg.N, 3, t_pass, cfg, laser))
    e_lin = lin_passes * _bank_pass_energy(cfg.M, cfg.L, 2, t_pass, cfg,
                                           laser)
    e_ecu = w.softmax_elems * ECU_SOFTMAX_E_PER_ELEM
    e_soa = w.act_elems * SOA_E_PER_ELEM
    energy = e_res + e_mha + e_lin + e_ecu + e_soa

    return SimReport(
        name=name or w.name,
        latency_s=latency,
        energy_j=energy,
        ops=w.total_ops_nominal,
        unit_latency={'residual': t_res, 'mha': t_mha, 'linear': t_lin,
                      'ecu': t_ecu},
        unit_energy={'residual': e_res, 'mha': e_mha, 'linear': e_lin,
                     'ecu': e_ecu, 'soa': e_soa},
    )


def ablation(w: Workload) -> Dict[str, SimReport]:
    """Paper Fig. 8: baseline / S/W-opt / pipelined / DAC-sharing / all."""
    base = DiffLightConfig(sparse_dataflow=False, pipelined=False,
                           dac_sharing=False)
    return {
        'baseline': simulate(w, base, 'baseline'),
        'sw_opt': simulate(w, dataclasses.replace(
            base, sparse_dataflow=True), 'sw_opt'),
        'pipelined': simulate(w, dataclasses.replace(
            base, pipelined=True), 'pipelined'),
        'dac_sharing': simulate(w, dataclasses.replace(
            base, dac_sharing=True), 'dac_sharing'),
        'combined': simulate(w, DiffLightConfig(), 'combined'),
    }


def dse_score(w: Workload, cfg: DiffLightConfig) -> float:
    """The paper's DSE metric: maximize GOPS / EPB."""
    r = simulate(w, cfg)
    return r.gops / r.epb_pj
