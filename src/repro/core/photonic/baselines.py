"""State-of-the-art comparison points (paper Figs. 9-10).

The paper reports only *ratios* against CPU / GPU / DeepCache / FPGA_Acc1 /
FPGA_Acc2 / PACE (the figures' absolute axes are not tabulated).  We
therefore anchor each baseline from the published average improvement
factors and DiffLight's simulated average — making the Fig. 9/10 benchmark a
consistency check of the claimed ratios, NOT an independent measurement of
the baselines.  The independently-reproduced results are the Fig. 8 ablation
(3x energy) and the DSE; this is recorded in EXPERIMENTS.md.

Published average factors (paper §V-B):
  GOPS:  CPU 59.5x, GPU 51.89x, DeepCache 192x, FPGA_Acc1 572x,
         FPGA_Acc2 94x, PACE 5.5x
  EPB (lower is better): CPU 32.9x, GPU 94.18x, DeepCache 376x,
         FPGA_Acc1 67x, FPGA_Acc2 3x, PACE 4.51x
"""
from __future__ import annotations

import dataclasses
from typing import Dict

GOPS_IMPROVEMENT = {
    'CPU (Xeon E5-2676v3)': 59.5,
    'GPU (RTX 4070)': 51.89,
    'DeepCache': 192.0,
    'FPGA_Acc1 (SDAcc)': 572.0,
    'FPGA_Acc2 (SDA)': 94.0,
    'PACE': 5.5,
}

EPB_IMPROVEMENT = {
    'CPU (Xeon E5-2676v3)': 32.9,
    'GPU (RTX 4070)': 94.18,
    'DeepCache': 376.0,
    'FPGA_Acc1 (SDAcc)': 67.0,
    'FPGA_Acc2 (SDA)': 3.0,
    'PACE': 4.51,
}


@dataclasses.dataclass
class BaselinePoint:
    name: str
    gops: float
    epb_pj: float


def derive_baselines(difflight_avg_gops: float,
                     difflight_avg_epb: float) -> Dict[str, BaselinePoint]:
    out = {}
    for name in GOPS_IMPROVEMENT:
        out[name] = BaselinePoint(
            name=name,
            gops=difflight_avg_gops / GOPS_IMPROVEMENT[name],
            epb_pj=difflight_avg_epb * EPB_IMPROVEMENT[name])
    return out
