"""Optoelectronic device parameters — paper Table II, verbatim — plus the
optical loss budget of §V used to size laser power.

All latencies in seconds, powers in watts.
"""
from __future__ import annotations

import dataclasses

NS = 1e-9
PS = 1e-12
US = 1e-6
MW = 1e-3
UW = 1e-6


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    latency: float      # s
    power: float        # W


# --- Table II ---------------------------------------------------------------
EO_TUNING = Device('EO tuning', 20 * NS, 4 * UW)
TO_TUNING = Device('TO tuning', 4 * US, 27.5 * MW)        # per FSR
VCSEL = Device('VCSEL', 0.07 * NS, 1.3 * MW)
PHOTODETECTOR = Device('Photodetector', 5.8 * PS, 2.8 * MW)
SOA = Device('SOA', 0.3 * NS, 2.2 * MW)
DAC_8B = Device('DAC (8-bit)', 0.29 * NS, 3 * MW)
ADC_8B = Device('ADC (8-bit)', 0.82 * NS, 3.1 * MW)
COMPARATOR = Device('Comparator', 623.7 * PS, 0.055 * MW)
SUBTRACTOR = Device('Subtractor', 719.95 * PS, 0.0028 * MW)
LUT = Device('LUT', 222.5 * PS, 4.21 * MW)

TABLE_II = [EO_TUNING, TO_TUNING, VCSEL, PHOTODETECTOR, SOA, DAC_8B, ADC_8B,
            COMPARATOR, SUBTRACTOR, LUT]


# --- optical losses (§V) ----------------------------------------------------
PROPAGATION_LOSS_DB_PER_CM = 1.0
SPLITTER_LOSS_DB = 0.13
MR_THROUGH_LOSS_DB = 0.02
MR_MODULATION_LOSS_DB = 0.72
MAX_MRS_PER_WAVEGUIDE = 36           # Lumerical-verified WDM limit (§V)
WAVEGUIDE_LENGTH_CM = 0.8            # per MR-bank column path (layout est.)
GROUP_INDEX = 4.2                    # Si waveguide -> propagation delay


def propagation_delay(length_cm: float = WAVEGUIDE_LENGTH_CM) -> float:
    c_cm_per_s = 2.998e10
    return length_cm * GROUP_INDEX / c_cm_per_s


def path_loss_db(n_mrs_on_waveguide: int,
                 length_cm: float = WAVEGUIDE_LENGTH_CM) -> float:
    """Loss along one waveguide: propagation + splitter + through losses of
    the other MRs + 2 modulation events (activation bank + weight bank)."""
    assert n_mrs_on_waveguide <= MAX_MRS_PER_WAVEGUIDE, \
        f'{n_mrs_on_waveguide} MRs exceeds the 36-MR WDM crosstalk limit'
    return (PROPAGATION_LOSS_DB_PER_CM * length_cm
            + SPLITTER_LOSS_DB
            + MR_THROUGH_LOSS_DB * max(n_mrs_on_waveguide - 2, 0)
            + 2 * MR_MODULATION_LOSS_DB)


def laser_power_factor(n_mrs_on_waveguide: int) -> float:
    """Multiplier on per-wavelength laser power to overcome path losses
    (PD sensitivity fixed)."""
    return 10.0 ** (path_loss_db(n_mrs_on_waveguide) / 10.0)
