"""Workload extraction: per-denoising-step operation counts of a UNet.

Walks the same structure as ``models.unet.init_unet`` (the two must stay in
sync — tests cross-check the MAC count against a jaxpr-derived count on a
small config) and produces the per-category totals the DiffLight simulator
maps onto its units.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.models.unet import UNetConfig


@dataclasses.dataclass
class Workload:
    name: str
    conv_macs: float = 0.0        # regular convs + 1x1 (Residual unit)
    convt_macs: float = 0.0       # transposed-conv MACs, *dense* count
    convt_zero_frac: float = 0.75  # fraction of convt MACs hitting zeros
    proj_macs: float = 0.0        # Q/K/V projections (head-block MR banks)
    linear_macs: float = 0.0      # out-proj / time-emb (linear+add block)
    attn_score_macs: float = 0.0  # Q.K^T
    attn_v_macs: float = 0.0      # attn . V
    softmax_elems: float = 0.0    # score elements through the ECU pipeline
    act_elems: float = 0.0        # swish activations (SOA blocks)
    norm_elems: float = 0.0       # broadband-MR normalizations
    batch: int = 1

    @property
    def total_macs_dense(self) -> float:
        return (self.conv_macs + self.convt_macs + self.proj_macs +
                self.linear_macs + self.attn_score_macs + self.attn_v_macs)

    def total_macs(self, sparse_dataflow: bool) -> float:
        convt = self.convt_macs * (1.0 - self.convt_zero_frac
                                   if sparse_dataflow else 1.0)
        return (self.conv_macs + convt + self.proj_macs + self.linear_macs +
                self.attn_score_macs + self.attn_v_macs)

    @property
    def total_ops_nominal(self) -> float:
        """Nominal ops (2 x dense MACs) — the numerator of GOPS."""
        return 2.0 * self.total_macs_dense

    def scale(self, f: float) -> 'Workload':
        out = dataclasses.replace(self)
        for fld in ('conv_macs', 'convt_macs', 'proj_macs', 'linear_macs',
                    'attn_score_macs', 'attn_v_macs', 'softmax_elems',
                    'act_elems', 'norm_elems'):
            setattr(out, fld, getattr(self, fld) * f)
        return out


def _attn_counts(w: Workload, S: int, C: int, heads: int,
                 ctx_len: Optional[int], ctx_dim: Optional[int]):
    # self-attention: Q/K/V in head blocks, out-proj in the linear block
    w.proj_macs += 3 * S * C * C
    w.linear_macs += S * C * C
    w.attn_score_macs += S * S * C
    w.attn_v_macs += S * S * C
    w.softmax_elems += heads * S * S
    if ctx_dim is not None and ctx_len:
        w.proj_macs += S * C * C + 2 * ctx_len * ctx_dim * C
        w.linear_macs += S * C * C
        w.attn_score_macs += S * ctx_len * C
        w.attn_v_macs += S * ctx_len * C
        w.softmax_elems += heads * S * ctx_len


def _res_counts(w: Workload, res: int, c_in: int, c_out: int, t_dim: int):
    hw = res * res
    w.norm_elems += hw * c_in
    w.act_elems += hw * c_in
    w.conv_macs += 9 * c_in * c_out * hw
    w.linear_macs += t_dim * c_out            # time-embedding projection
    w.norm_elems += hw * c_out
    w.act_elems += hw * c_out
    w.conv_macs += 9 * c_out * c_out * hw
    if c_in != c_out:
        w.conv_macs += c_in * c_out * hw      # 1x1 skip


def unet_workload(cfg: UNetConfig, batch: int = 1,
                  ctx_len: Optional[int] = 77) -> Workload:
    """Per-denoising-step op counts for one UNet forward (batch=1), walked
    level-by-level in lockstep with ``init_unet``."""
    w = Workload(name=cfg.name, batch=batch)
    t_dim = cfg.base_ch * 4
    ctx_dim = cfg.context_dim
    # time MLP
    w.linear_macs += cfg.base_ch * t_dim + t_dim * t_dim
    w.act_elems += t_dim
    res = cfg.img_size
    ch = cfg.base_ch
    w.conv_macs += 9 * cfg.in_ch * cfg.base_ch * res * res
    chs = [cfg.base_ch]
    for lvl, mult in enumerate(cfg.ch_mults):
        out_ch = cfg.base_ch * mult
        for _ in range(cfg.n_res_blocks):
            _res_counts(w, res, ch, out_ch, t_dim)
            ch = out_ch
            if res in cfg.attn_resolutions:
                w.norm_elems += res * res * ch
                _attn_counts(w, res * res, ch, cfg.n_heads, ctx_len, ctx_dim)
            chs.append(ch)
        if lvl < len(cfg.ch_mults) - 1:
            w.conv_macs += 9 * ch * ch * (res // 2) ** 2
            chs.append(ch)
            res //= 2
    # mid
    _res_counts(w, res, ch, ch, t_dim)
    w.norm_elems += res * res * ch
    _attn_counts(w, res * res, ch, cfg.n_heads, ctx_len, ctx_dim)
    _res_counts(w, res, ch, ch, t_dim)
    # up
    for lvl, mult in reversed(list(enumerate(cfg.ch_mults))):
        out_ch = cfg.base_ch * mult
        for _ in range(cfg.n_res_blocks + 1):
            skip_ch = chs.pop()
            _res_counts(w, res, ch + skip_ch, out_ch, t_dim)
            ch = out_ch
            if res in cfg.attn_resolutions:
                w.norm_elems += res * res * ch
                _attn_counts(w, res * res, ch, cfg.n_heads, ctx_len, ctx_dim)
        if lvl > 0:
            # stride-2 4x4 transposed conv (C4 target): dense MAC count on
            # the zero-inserted grid; 1 - 1/s^2 of them hit zeros
            res *= 2
            w.convt_macs += 16 * ch * ch * res * res
    w.norm_elems += res * res * ch
    w.act_elems += res * res * ch
    w.conv_macs += 9 * ch * cfg.in_ch * res * res
    if batch != 1:
        w = w.scale(batch)
        w.batch = batch
    return w
