"""Analog-noise robustness model (paper §VI future work: "mitigating
fabrication process variations to further improve reliability").

Non-coherent photonic MACs are analog: MR transmission calibration error,
thermal drift between TO re-tunes, inter-channel crosstalk (bounded by the
36-MR WDM limit) and PD shot noise all perturb the effective weights and
partial sums.  We model the aggregate as

    y = (x_q + eps_x) (w_q + eps_w) + eps_pd

with eps_* zero-mean Gaussians expressed in LSBs of the 8-bit datapath, and
provide (a) a noisy variant of the W8A8 matmul for robustness sweeps and
(b) the crosstalk-vs-channel-count curve that justifies the paper's
36-MRs-per-waveguide design point.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, quantize, quantize_per_channel


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    sigma_w_lsb: float = 0.3     # MR calibration + thermal drift (weights)
    sigma_x_lsb: float = 0.2     # activation modulation error
    sigma_pd_lsb: float = 0.5    # BPD / shot noise on the accumulated sum
    crosstalk_db_per_channel: float = -28.0   # adjacent-channel isolation


def crosstalk_sigma_lsb(n_channels: int, model: NoiseModel) -> float:
    """Aggregate crosstalk contribution (in LSBs of the output) of the other
    n-1 wavelengths on one waveguide.  Grows ~linearly in channel count at
    fixed isolation — the quantitative reason a waveguide is capped at 36
    MRs (paper §V, Lumerical analysis)."""
    # pure-Python math: the result is a trace-time constant, so the noisy
    # matmul stays jittable (the engine compiles it into its step)
    leak = 10.0 ** (model.crosstalk_db_per_channel / 10.0)
    return math.sqrt(max(n_channels - 1, 0) * leak) * 127.0


def noisy_w8a8_matmul(key, x: jax.Array, w, model: NoiseModel = NoiseModel(),
                      n_channels: int = 36) -> jax.Array:
    """W8A8 matmul with analog perturbations (pure-jnp).  Serves both the
    robustness sweeps and the engine's ``w8a8+noise`` precision policy;
    ``w`` may be a float weight or a pre-quantized QTensor.  Deterministic
    under a fixed ``key`` — the same key reproduces the same analog draw."""
    kx, kw, kp = jax.random.split(key, 3)
    xq = quantize(x.reshape(-1, x.shape[-1]), axis=(1,))
    wq = w if isinstance(w, QTensor) else quantize_per_channel(w)
    xn = xq.q.astype(jnp.float32) + \
        model.sigma_x_lsb * jax.random.normal(kx, xq.q.shape)
    wn = wq.q.astype(jnp.float32) + \
        model.sigma_w_lsb * jax.random.normal(kw, wq.q.shape)
    acc = xn @ wn
    sigma_out = jnp.sqrt(model.sigma_pd_lsb ** 2 +
                         crosstalk_sigma_lsb(n_channels, model) ** 2)
    acc = acc + sigma_out * jax.random.normal(kp, acc.shape) * \
        jnp.sqrt(jnp.asarray(x.shape[-1], jnp.float32))
    out = acc * xq.scale * wq.scale.reshape(1, -1)
    return out.reshape(x.shape[:-1] + (wq.q.shape[-1],))


def robustness_sweep(key, x: jax.Array, w: jax.Array,
                     channel_counts=(2, 8, 16, 24, 36, 48, 64),
                     model: NoiseModel = NoiseModel()):
    """Relative output error vs WDM channel count: reproduces the shape of
    the paper's error-free-operation constraint (<=36 channels).  Returns
    {channels: rel_l2_error}."""
    exact = x.reshape(-1, x.shape[-1]) @ w
    out = {}
    for i, n in enumerate(channel_counts):
        y = noisy_w8a8_matmul(jax.random.fold_in(key, i), x, w,
                              model=model, n_channels=n)
        rel = float(jnp.linalg.norm(y.reshape(exact.shape) - exact) /
                    jnp.linalg.norm(exact))
        out[n] = rel
    return out
