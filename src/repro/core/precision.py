"""Precision-policy API: ONE type describing how matmuls execute.

The paper's 3x energy claim rests on 8-bit analog photonic compute, so
"how precise is this UNet evaluation" is a first-class serving decision,
not a boolean.  A ``PrecisionPolicy`` bundles everything the execution
path needs — backend, bit-width, analog-noise model, calibration mode —
into a single frozen (hashable) value that call sites close over, so a
jitted step is specialized per policy and adding a future policy (e.g.
per-layer mixed precision) touches this type instead of every call site.

Built-in policies:

  * ``PrecisionPolicy.fp32()``       — full-precision digital baseline;
  * ``PrecisionPolicy.w8a8()``       — DiffLight W8A8 analog path (C1):
    per-output-channel weight scales, dynamic per-row activation scales;
  * ``PrecisionPolicy.w8a8_noise()`` — W8A8 plus the analog perturbation
    model of ``core/photonic/noise.py`` (MR calibration error, thermal
    drift, PD shot noise, WDM crosstalk).

The legacy ``quant: bool`` flag threaded through ``layers.linear``,
``unet_apply`` and ``DiffusionPipeline`` is deprecated; ``resolve``
keeps a one-release shim mapping ``quant=True`` to ``w8a8()``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax

from repro.core.photonic.noise import NoiseModel

#: request-level precision names accepted by the serving engine
PRECISION_NAMES = ('fp32', 'w8a8', 'w8a8+noise')

#: activation/weight calibration modes ('dynamic': per-row activation
#: scales computed at run time; 'prequant': weights pre-quantized to
#: QTensors at build time, activations still dynamic)
CALIBRATIONS = ('dynamic', 'prequant')


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How matmuls execute: backend, bit-width, noise, calibration.

    Frozen + hashable so a policy can key jit caches and be closed over
    by compiled step functions.  ``noise_seed`` anchors the noise PRNG
    when the caller does not thread an explicit key (determinism under a
    fixed seed is a test invariant).
    """
    backend: str = 'fp32'                  # 'fp32' | 'w8a8'
    bits: int = 32                         # operand bit-width
    noise: Optional[NoiseModel] = None     # analog perturbations (w8a8 only)
    noise_seed: int = 0                    # PRNG anchor when no key threaded
    n_channels: int = 36                   # WDM channels (crosstalk model)
    calibration: str = 'dynamic'

    def __post_init__(self):
        if self.backend not in ('fp32', 'w8a8'):
            raise ValueError(f'unknown precision backend {self.backend!r}')
        if self.calibration not in CALIBRATIONS:
            raise ValueError(f'unknown calibration {self.calibration!r}')
        if self.backend == 'fp32' and self.noise is not None:
            raise ValueError('noise model requires the w8a8 backend')

    # -- constructors ------------------------------------------------------
    @classmethod
    def fp32(cls) -> 'PrecisionPolicy':
        return cls()

    @classmethod
    def w8a8(cls, calibration: str = 'dynamic') -> 'PrecisionPolicy':
        return cls(backend='w8a8', bits=8, calibration=calibration)

    @classmethod
    def w8a8_noise(cls, model: Optional[NoiseModel] = None,
                   noise_seed: int = 0,
                   n_channels: int = 36) -> 'PrecisionPolicy':
        return cls(backend='w8a8', bits=8, noise=model or NoiseModel(),
                   noise_seed=noise_seed, n_channels=n_channels)

    @classmethod
    def from_name(cls, name: str) -> 'PrecisionPolicy':
        if name == 'fp32':
            return cls.fp32()
        if name == 'w8a8':
            return cls.w8a8()
        if name == 'w8a8+noise':
            return cls.w8a8_noise()
        raise ValueError(f'unknown precision {name!r} '
                         f'(expected one of {PRECISION_NAMES})')

    # -- views -------------------------------------------------------------
    @property
    def name(self) -> str:
        if self.backend == 'fp32':
            return 'fp32'
        return 'w8a8+noise' if self.noise is not None else 'w8a8'

    @property
    def quantized(self) -> bool:
        return self.backend == 'w8a8'

    @property
    def noisy(self) -> bool:
        return self.noise is not None


def resolve(policy=None, quant: Optional[bool] = None) -> PrecisionPolicy:
    """Coerce (policy, legacy quant flag) to one PrecisionPolicy.

    Accepts a PrecisionPolicy, a precision name string, or (shim) a bool
    that slipped into the policy slot positionally.  ``quant=True`` maps
    to ``w8a8()`` with a DeprecationWarning — remove after one release.
    """
    if isinstance(policy, bool):            # legacy positional quant flag
        policy, quant = None, policy
    if policy is not None:
        if isinstance(policy, str):
            return PrecisionPolicy.from_name(policy)
        return policy
    if quant:
        warnings.warn(
            'quant=True is deprecated; pass '
            'policy=PrecisionPolicy.w8a8() instead',
            DeprecationWarning, stacklevel=3)
        return PrecisionPolicy.w8a8()
    return PrecisionPolicy.fp32()


class NoiseKeyStream:
    """Trace-time PRNG key dispenser for analog-noise injection.

    Each noisy matmul call site gets ``fold_in(base, i)`` with a Python
    counter that advances at trace time, so every layer draws independent
    noise while the whole network stays deterministic under a fixed base
    key.  A stream built from ``None`` dispenses ``None`` (no noise) —
    callers never need to branch.
    """

    def __init__(self, base_key):
        self._base = base_key
        self._i = 0

    def next(self):
        if self._base is None:
            return None
        k = jax.random.fold_in(self._base, self._i)
        self._i += 1
        return k


def stream_for(policy: PrecisionPolicy, noise_key=None) -> NoiseKeyStream:
    """The noise-key stream an apply function should dispense from:
    the caller's key when threaded, else the policy's seed anchor, else
    an inert stream for noise-free policies."""
    if not policy.noisy:
        return NoiseKeyStream(None)
    if noise_key is None:
        noise_key = jax.random.PRNGKey(policy.noise_seed)
    return NoiseKeyStream(noise_key)
