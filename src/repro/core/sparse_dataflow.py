"""Sparsity-aware transposed-convolution dataflow (paper §IV-C).

A stride-s transposed convolution first zero-inserts (s-1) zeros between
input pixels, then runs a dense convolution; (s^2-1)/s^2 of the MACs hit
inserted zeros.  DiffLight's dataflow detects all-zero columns of the
flattened input and drops the matching kernel elements.

The exact TPU-native equivalent is the *sub-pixel decomposition*: a stride-s
ConvTranspose with kernel k equals s^2 independent dense stride-1
convolutions over the **un-expanded** input — one per output phase
(oy mod s, ox mod s) — whose outputs are interleaved.  Each phase convolution
uses exactly the kernel taps that land on non-zero inputs, so the zero-MACs
are eliminated *structurally* (the same arithmetic the paper saves, but in
MXU-friendly dense GEMMs instead of MR-bank column-skipping).

Layout: NHWC activations, HWIO kernels (the kernel is the *gradient* /
fractional-stride orientation used by jax.lax.conv_transpose).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def conv_transpose_dense(x: jax.Array, kernel: jax.Array, stride: int,
                         padding: str = 'SAME') -> jax.Array:
    """Reference: XLA's fractional-stride transposed conv (computes against
    the zero-inserted input — the 'baseline dataflow' of the paper)."""
    return jax.lax.conv_transpose(
        x, kernel, (stride, stride), padding,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'))


def _phase_taps(kh: int, kw: int, stride: int, phase_y: int, phase_x: int,
                pad_top: int, pad_left: int):
    """Static index math: which kernel taps contribute to output phase
    (phase_y, phase_x), and the input offset they read from.

    Output pixel oy reads zero-inserted row  z = oy + dy - pad_top  for kernel
    row dy (flipped orientation handled below); z is a real input row iff
    z % stride == 0.  So for fixed oy % stride == phase_y the contributing dys
    are { dy : (phase_y + dy - pad_top) % stride == 0 }.
    """
    tys = [dy for dy in range(kh) if (phase_y + dy - pad_top) % stride == 0]
    txs = [dx for dx in range(kw) if (phase_x + dx - pad_left) % stride == 0]
    return tys, txs


def conv_transpose_sparse(x: jax.Array, kernel: jax.Array, stride: int,
                          padding: str = 'SAME') -> jax.Array:
    """Zero-skipping transposed conv via sub-pixel decomposition.

    x:      (N, H, W, Cin)
    kernel: (kh, kw, Cin, Cout)   (conv_transpose / HWIO orientation)
    Returns (N, H*stride, W*stride, Cout) for SAME padding.
    Only SAME padding and square stride are supported (the UNet decoder case).
    """
    if stride == 1:
        return conv_transpose_dense(x, kernel, 1, padding)
    if padding != 'SAME':
        raise NotImplementedError('sparse dataflow supports SAME padding')
    N, H, W, Cin = x.shape
    kh, kw, _, Cout = kernel.shape
    out_h, out_w = H * stride, W * stride
    # Match jax.lax.conv_transpose(SAME): it runs conv_general_dilated with
    # lhs_dilation=s and padding (pad_a, pad_b) where
    #   pad_a = k-1 if s > k-1 else ceil((k+s-2)/2),  pad_a+pad_b = k+s-2.
    # Semantics (correlation, no kernel flip):
    #   out[o] = sum_d ker[d] * x[(o + d - pad_a)/s]   (when divisible, in range)
    def _pad_a(k, s):
        return k - 1 if s > k - 1 else -(-(k + s - 2) // 2)
    pad_top = _pad_a(kh, stride)
    pad_left = _pad_a(kw, stride)
    # Per output phase py = o mod s the contributing taps are
    #   { d : (py + d - pad_a) % s == 0 }, reading input offset
    #   off_d = (py + d - pad_a) // s  relative to oi = o // s.
    out = jnp.zeros((N, out_h, out_w, Cout), x.dtype)
    for py in range(stride):
        tys = [dy for dy in range(kh) if (py + dy - pad_top) % stride == 0]
        for px in range(stride):
            txs = [dx for dx in range(kw) if (px + dx - pad_left) % stride == 0]
            if not tys or not txs:
                continue
            sub_k = kernel[jnp.array(tys)][:, jnp.array(txs)]  # (ty, tx, Cin, Cout)
            off_y = [(py + dy - pad_top) // stride for dy in tys]
            off_x = [(px + dx - pad_left) // stride for dx in txs]
            # A dense conv with arbitrary per-tap offsets == conv with the
            # sub-kernel laid out on the offset grid.  Offsets are contiguous
            # descending by construction; flip to ascending conv layout.
            oy0, oy1 = min(off_y), max(off_y)
            ox0, ox1 = min(off_x), max(off_x)
            grid = jnp.zeros((oy1 - oy0 + 1, ox1 - ox0 + 1, Cin, Cout),
                             kernel.dtype)
            for a, dy in enumerate(off_y):
                for b, dx in enumerate(off_x):
                    grid = grid.at[dy - oy0, dx - ox0].set(sub_k[a, b])
            # output phase pixel oi reads input rows oi+oy0 .. oi+oy1 ->
            # forward conv VALID on x padded by (-oy0 on top? ) Use explicit
            # padding: need x[oi + off] for oi in [0, H); pad lo = -oy0 if
            # oy0<0 else 0 etc.  Conv (flip? lax.conv_general_dilated
            # correlates, matching x[i + dy] indexing with kernel[dy]).
            # out[oi] = sum_d x[oi + oy0 + d] * grid[d]; with correlation
            # semantics out[i] = sum_d xpad[i+d]*k[d] we need pad_lo = -oy0
            # and pad_hi = oy1 (negative pad crops).
            res = jax.lax.conv_general_dilated(
                x, grid,
                window_strides=(1, 1),
                padding=((-oy0, oy1), (-ox0, ox1)),
                dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
            out = out.at[:, py::stride, px::stride, :].set(res)
    return out


def zero_mac_fraction(kh: int, kw: int, stride: int) -> float:
    """Fraction of baseline transposed-conv MACs that hit inserted zeros
    (what the sparse dataflow saves): 1 - 1/s^2 for k >= s."""
    dense = kh * kw
    live = -(-kh // stride) * (-(-kw // stride))  # ceil(k/s)^2 on average
    return 1.0 - live / dense
