"""W8A8 symmetric quantization (paper §V, [28] Q-Diffusion style).

DiffLight imprints 8-bit activations and weights onto MR banks; the balanced
photodetector accumulates the signed analog sum.  The exact digital semantic
is an int8 x int8 -> int32 GEMM with symmetric per-channel scales: the MR
transmission calibration corresponds to the scale factors, the positive /
negative waveguide rails correspond to the sign of the int8 value.

This module provides the quantize / dequantize machinery and a `QTensor`
pytree so quantized weights flow through jit / pjit unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    """An int8 tensor with a broadcastable float scale: x ~= q * scale."""

    q: jax.Array      # int8
    scale: jax.Array  # f32, broadcastable against q

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey('q'), self.q),
                 (jax.tree_util.GetAttrKey('scale'), self.scale)), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _absmax_scale(x: jax.Array, axis, eps: float = 1e-8) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / INT8_MAX


def quantize(x: jax.Array, axis: Optional[Tuple[int, ...]] = None) -> QTensor:
    """Symmetric quantization.

    axis: axes to *reduce* when computing the scale.  ``None`` -> per-tensor.
    E.g. a weight (in, out) quantized per-output-channel uses ``axis=(0,)``.
    """
    if axis is None:
        axis = tuple(range(x.ndim))
    scale = _absmax_scale(x.astype(jnp.float32), axis)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_MAX, INT8_MAX)
    return QTensor(q.astype(jnp.int8), scale)


def quantize_per_channel(w: jax.Array) -> QTensor:
    """Weight (..., in, out): one scale per output channel — the reduction
    runs over the contracting (in) dim only, so batched/expert weights get
    per-(expert, channel) scales."""
    return quantize(w, axis=(w.ndim - 2,))


def fake_quantize(x: jax.Array, axis=None) -> jax.Array:
    """Quantize-dequantize round trip in the input dtype (for QAT / error
    measurement)."""
    return quantize(x, axis=axis).dequantize(x.dtype)


def quantization_error(x: jax.Array, axis=None) -> jax.Array:
    """Relative L2 error of the W8A8 round trip (Table-I quality proxy)."""
    xq = fake_quantize(x, axis=axis)
    return jnp.linalg.norm((x - xq).ravel()) / jnp.maximum(
        jnp.linalg.norm(x.ravel()), 1e-12)


def quantize_params(params, min_size: int = 1 << 12):
    """Serve-time weight quantization (paper C1): every float matmul weight
    (>= min_size elements, >= 2-D) becomes a QTensor with per-output-channel
    scales; everything else (norms, biases, embeddings for gather) stays
    float.  Halves (vs bf16) / quarters (vs f32) the weight bytes a decode
    step reads from HBM."""
    def one(path, leaf):
        name = str(getattr(path[-1], 'key', '')) if path else ''
        is_weight = name in ('w', 'w_gate', 'w_up', 'w_down')
        if (is_weight and hasattr(leaf, 'ndim') and leaf.ndim >= 2
                and leaf.dtype in (jnp.float32, jnp.bfloat16)
                and leaf.size >= min_size):
            return quantize_per_channel(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(one, params)


def w8a8_matmul_ref(x: jax.Array, wq: QTensor,
                    out_dtype=jnp.float32) -> jax.Array:
    """Reference W8A8 matmul: dynamic per-row activation quantization,
    int8 x int8 -> int32 accumulate, rescale.  Mirrors one pass through a
    DiffLight MR bank pair + BPD column.

    x:  (..., K) float
    wq: QTensor with q (K, N)
    """
    xq = quantize(x, axis=(x.ndim - 1,))  # per-row (per optical 'vector')
    acc = jax.lax.dot_general(
        xq.q, wq.q,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * xq.scale * wq.scale.reshape(1, -1)
            ).astype(out_dtype)
