"""Log-sum-exp softmax decomposition (paper Eq. 4) and its streaming form.

DiffLight decomposes softmax into four pipelined sub-operations executed in
the electronic control unit while attention scores stream out of the ADCs:

  1. track the running maximum gamma_max        (comparator circuit)
  2. compute ln(sum_j exp(gamma_j - gamma_max)) (LUT exp + accumulate + LUT ln)
  3. subtract:   gamma_i - gamma_max - ln(...)  (subtractor circuit)
  4. exponentiate the result                    (LUT exp)

On TPU this *streaming max + LSE accumulation* is exactly the online-softmax
recurrence of flash attention: process the score vector in blocks, keep
(m, l) = (running max, running sum of exp), and renormalize.  This module
holds the decomposition itself plus the blockwise streaming update used by
``kernels/flash_attention``; the Pallas kernel is the VMEM-tiled version.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def lse_softmax(scores: jax.Array, axis: int = -1) -> jax.Array:
    """Softmax via the paper's 4-op decomposition.  Numerically identical to
    jax.nn.softmax (which uses the same stabilization)."""
    gamma_max = jnp.max(scores, axis=axis, keepdims=True)            # op 1
    shifted = scores - gamma_max
    ln_sum = jnp.log(jnp.sum(jnp.exp(shifted), axis=axis,            # op 2
                             keepdims=True))
    return jnp.exp(shifted - ln_sum)                                  # ops 3+4


class StreamState(NamedTuple):
    """Running (gamma_max, sum-of-exp, unnormalized accumulator)."""
    m: jax.Array    # (..., 1) running max
    l: jax.Array    # (..., 1) running sum of exp(score - m)
    acc: jax.Array  # (..., d_v) running weighted-value accumulator


def stream_init(batch_shape: Tuple[int, ...], d_v: int,
                dtype=jnp.float32) -> StreamState:
    return StreamState(
        m=jnp.full(batch_shape + (1,), NEG_INF, dtype),
        l=jnp.zeros(batch_shape + (1,), dtype),
        acc=jnp.zeros(batch_shape + (d_v,), dtype),
    )


def stream_update(state: StreamState, scores_blk: jax.Array,
                  values_blk: jax.Array) -> StreamState:
    """One streaming step: fold in a block of scores (..., B) and the matching
    value rows (..., B, d_v) — value rows broadcast over any extra leading
    query dims of the scores.  This is the comparator + LUT pipeline of the
    paper, blockified."""
    m_blk = jnp.max(scores_blk, axis=-1, keepdims=True)
    m_new = jnp.maximum(state.m, m_blk)                              # op 1
    correction = jnp.exp(state.m - m_new)
    p = jnp.exp(scores_blk - m_new)                                  # op 4 (partial)
    l_new = state.l * correction + jnp.sum(p, axis=-1, keepdims=True)
    v = values_blk.astype(p.dtype)
    if p.ndim == v.ndim:        # p (..., S, B) x v (..., B, d)
        pv = jnp.matmul(p, v)
    else:                        # p (..., B)    x v (..., B, d)
        pv = jnp.einsum('...b,...bd->...d', p, v)
    acc_new = state.acc * correction + pv
    return StreamState(m_new, l_new, acc_new)


def stream_finalize(state: StreamState) -> jax.Array:
    """ops 2+3: divide by exp(ln_sum) = l."""
    return state.acc / jnp.maximum(state.l, 1e-30)


def streaming_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                            block: int = 128, causal: bool = False,
                            scale: float | None = None) -> jax.Array:
    """Pure-jnp streaming attention over K/V blocks: the oracle for the
    Pallas flash kernel, and a direct executable rendering of the paper's
    pipelined softmax.  q (..., S, d), k/v (..., T, d)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    T = k.shape[-2]
    S = q.shape[-2]
    pad = (-T) % block
    if pad:
        kp = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)])
        vp = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
    else:
        kp, vp = k, v
    nblk = kp.shape[-2] // block
    q32 = q.astype(jnp.float32) * scale
    state = stream_init(q.shape[:-1], v.shape[-1])
    kv_pos = jnp.arange(block)
    q_pos = jnp.arange(S)

    def body(i, state):
        kb = jax.lax.dynamic_slice_in_dim(kp, i * block, block, axis=-2)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * block, block, axis=-2)
        s = jnp.einsum('...sd,...td->...st', q32, kb.astype(jnp.float32))
        col = i * block + kv_pos                      # (block,)
        mask = col[None, :] < T                       # padding mask
        if causal:
            mask = mask & (col[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)
        return stream_update(state, s, vb)

    state = jax.lax.fori_loop(0, nblk, body, state)
    return stream_finalize(state).astype(q.dtype)
