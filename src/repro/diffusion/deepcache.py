"""DeepCache (Ma et al., CVPR 2024) — the paper's strongest *algorithmic*
baseline (Figs. 9-10): cache the deep (low-resolution) UNet features across
adjacent timesteps and recompute only the shallow layers on "skip" steps.

Rationale: in the reverse diffusion trajectory the deep features evolve
slowly; re-running only the outermost level every step recovers most of the
quality at a fraction of the MACs.  We implement the standard interval
variant: a full pass every ``interval`` steps refreshes the cache; skip
steps reuse the cached deepest up-path activation.

This exists (a) as a runnable serving mode (`pipeline_deepcache`) and (b) as
a workload transform for the photonic simulator, so the Fig. 9/10 DeepCache
comparison point can also be *derived* instead of anchored.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.unet import (UNetConfig, attn_block, resblock,
                               timestep_embedding, _gn_swish)


def unet_apply_cached(p, cfg: UNetConfig, x: jax.Array, t: jax.Array,
                      cache: Optional[jax.Array], refresh: bool,
                      context=None, policy=None, *, noise_key=None
                      ) -> Tuple[jax.Array, jax.Array]:
    """UNet forward with DeepCache.

    refresh=True  : full pass; returns (eps, new_cache) where the cache is
                    the activation entering the LAST up level.
    refresh=False : recompute only the outermost (full-resolution) down
                    blocks and the last up level, splicing in the cached
                    deep activation.
    Static `refresh` (two jitted variants), matching the interval schedule.
    ``policy`` selects the matmul precision (PrecisionPolicy; the legacy
    positional bool still resolves).
    """
    from repro.core.precision import resolve, stream_for
    pol = resolve(policy)
    keys = stream_for(pol, noise_key)
    g = cfg.groups
    t_emb = timestep_embedding(t, cfg.base_ch)
    t_emb = L.linear(p['t_mlp2'], L.swish(L.linear(p['t_mlp1'], t_emb)))
    h = L.conv2d(p['conv_in'], x)
    skips = [h]
    # --- outermost down level (always computed) ---
    lvl0 = p['down'][0]
    res = cfg.img_size
    for b in lvl0['blocks']:
        h = resblock(b['res'], h, t_emb, g)
        if 'attn' in b:
            h = attn_block(b['attn'], h, g, cfg.n_heads, context, pol, keys)
        skips.append(h)

    if refresh or cache is None:
        hh = h
        deep_skips = []
        if 'down' in lvl0:
            hh = L.conv2d(lvl0['down'], hh, stride=2)
            deep_skips.append(hh)
        for lvl_p in p['down'][1:]:
            for b in lvl_p['blocks']:
                hh = resblock(b['res'], hh, t_emb, g)
                if 'attn' in b:
                    hh = attn_block(b['attn'], hh, g, cfg.n_heads, context,
                                    pol, keys)
                deep_skips.append(hh)
            if 'down' in lvl_p:
                hh = L.conv2d(lvl_p['down'], hh, stride=2)
                deep_skips.append(hh)
        hh = resblock(p['mid']['res1'], hh, t_emb, g)
        hh = attn_block(p['mid']['attn'], hh, g, cfg.n_heads, context, pol, keys)
        hh = resblock(p['mid']['res2'], hh, t_emb, g)
        for lvl_p in p['up'][:-1]:
            for b in lvl_p['blocks']:
                hh = jnp.concatenate([hh, deep_skips.pop()], axis=-1)
                hh = resblock(b['res'], hh, t_emb, g)
                if 'attn' in b:
                    hh = attn_block(b['attn'], hh, g, cfg.n_heads, context,
                                    pol, keys)
            if 'upconv' in lvl_p:
                hh = L.conv_transpose2d(lvl_p['upconv'], hh, stride=2,
                                        sparse_dataflow=cfg.sparse_dataflow)
        new_cache = hh                  # activation entering the last level
    else:
        new_cache = cache

    # --- outermost up level (always computed) ---
    h_up = new_cache
    for b in p['up'][-1]['blocks']:
        h_up = jnp.concatenate([h_up, skips.pop()], axis=-1)
        h_up = resblock(b['res'], h_up, t_emb, g)
        if 'attn' in b:
            h_up = attn_block(b['attn'], h_up, g, cfg.n_heads, context,
                              pol, keys)
    h_up = _gn_swish(p['gn_out'], h_up, g)
    return L.conv2d(p['conv_out'], h_up), new_cache


def shallow_workload_fraction(cfg: UNetConfig) -> float:
    """MAC fraction of one *skip* (shallow) pass vs one full UNet pass.

    A skip step recomputes only the outermost down level + last up level
    + in/out convs; we approximate that by the full-resolution share of
    the MAC count.  This single source feeds both the derived DeepCache
    simulator point and the serving engine's photonic accountant, which
    bills skip ticks at this fraction of a full-UNet tick.
    """
    from repro.core.photonic.workload import unet_workload
    full = unet_workload(cfg).total_macs_dense
    shallow_cfg = UNetConfig(
        name=cfg.name + '-shallow', img_size=cfg.img_size, in_ch=cfg.in_ch,
        base_ch=cfg.base_ch, ch_mults=cfg.ch_mults[:1],
        n_res_blocks=cfg.n_res_blocks,
        attn_resolutions=cfg.attn_resolutions, n_heads=cfg.n_heads,
        context_dim=cfg.context_dim)
    return unet_workload(shallow_cfg).total_macs_dense / full


def deepcache_workload_factor(cfg: UNetConfig, interval: int = 5) -> float:
    """Average per-step MAC fraction vs the full UNet (for the simulator's
    derived DeepCache point): 1 full pass + (interval-1) shallow passes."""
    s = shallow_workload_fraction(cfg)
    return (1.0 + (interval - 1) * s) / interval
