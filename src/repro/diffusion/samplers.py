"""Reverse-process samplers: DDPM ancestral (paper Eq. 2) and DDIM."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.schedule import Schedule

# eps_fn(x_t, t_batch) -> predicted noise
EpsFn = Callable[[jax.Array, jax.Array], jax.Array]


def ddpm_step(sched: Schedule, eps_fn: EpsFn, x_t: jax.Array, t: jax.Array,
              key: jax.Array) -> jax.Array:
    """One reverse step (Eq. 2): x_{t-1} = mu_theta(x_t, t) + sigma_t z."""
    B = x_t.shape[0]
    tb = jnp.full((B,), t, jnp.int32)
    eps = eps_fn(x_t, tb)
    beta = sched.betas[t]
    alpha = sched.alphas[t]
    ab = sched.alpha_bars[t]
    mu = (x_t - beta / jnp.sqrt(1.0 - ab) * eps) / jnp.sqrt(alpha)
    sigma = jnp.sqrt(beta)
    z = jax.random.normal(key, x_t.shape, x_t.dtype)
    return mu + jnp.where(t > 0, sigma, 0.0) * z


def ddpm_sample(sched: Schedule, eps_fn: EpsFn, shape, key: jax.Array,
                dtype=jnp.float32) -> jax.Array:
    """Full T-step ancestral sampling from pure noise."""
    k0, kloop = jax.random.split(key)
    x_T = jax.random.normal(k0, shape, dtype)

    def body(i, carry):
        x, k = carry
        t = sched.T - 1 - i
        k, ks = jax.random.split(k)
        return ddpm_step(sched, eps_fn, x, t, ks), k

    x0, _ = jax.lax.fori_loop(0, sched.T, body, (x_T, kloop))
    return x0


def ddim_timesteps(sched: Schedule, steps: int) -> np.ndarray:
    """The uniform DDIM sub-sequence of `steps` timesteps (T-1 ... 0).

    Computed host-side (numpy): the serving engine builds per-request
    trajectories on the admission path, where an eager jnp.linspace would
    trigger one XLA compile per distinct `steps` value.  Both the batch
    sampler and the engine read this single source, so their timestep
    sequences agree by construction.
    """
    return np.linspace(sched.T - 1, 0, steps).astype(np.int32)


def ddim_step(sched: Schedule, eps: jax.Array, x: jax.Array, t: jax.Array,
              t_prev: jax.Array, eta: float = 0.0,
              key: Optional[jax.Array] = None,
              return_x0: bool = False) -> jax.Array:
    """One DDIM update x_t -> x_{t_prev}, given the predicted noise `eps`.

    Vectorizes over *per-sample* timesteps: `t` / `t_prev` may be scalars or
    (B,) int vectors, so samples at different denoising depths share one
    call (the continuous-batching engine's mixed-timestep step).  A
    `t_prev < 0` entry means "step to x_0" (alpha_bar_prev = 1).

    ``return_x0=True`` additionally returns the clean-image prediction
    ``x0_pred`` the update is built on — the convergence signal the
    serving engine's speculative early-exit tracks (``||x0_t - x0_{t-1}||``
    flat for several ticks means further steps no longer move the image).
    """
    B = x.shape[0]
    bshape = (B,) + (1,) * (x.ndim - 1)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    t_prev = jnp.broadcast_to(jnp.asarray(t_prev, jnp.int32), (B,))
    ab_t = sched.alpha_bars[t].reshape(bshape)
    ab_prev = jnp.where(t_prev >= 0,
                        sched.alpha_bars[jnp.maximum(t_prev, 0)],
                        1.0).reshape(bshape)
    x0_pred = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
    sigma = eta * jnp.sqrt((1 - ab_prev) / (1 - ab_t)) * \
        jnp.sqrt(1 - ab_t / ab_prev)
    x_prev = jnp.sqrt(ab_prev) * x0_pred + \
        jnp.sqrt(jnp.maximum(1 - ab_prev - sigma ** 2, 0.0)) * eps
    if key is not None:
        x_prev = x_prev + sigma * jax.random.normal(key, x.shape, x.dtype)
    if return_x0:
        return x_prev, x0_pred
    return x_prev


def ddim_sample(sched: Schedule, eps_fn: EpsFn, shape, key: jax.Array,
                steps: int = 50, eta: float = 0.0,
                dtype=jnp.float32) -> jax.Array:
    """DDIM with a uniform sub-sequence of `steps` timesteps."""
    ts = jnp.asarray(ddim_timesteps(sched, steps))
    k0, kloop = jax.random.split(key)
    x = jax.random.normal(k0, shape, dtype)

    def body(i, carry):
        x, k = carry
        t = ts[i]
        t_prev = jnp.where(i + 1 < steps, ts[jnp.minimum(i + 1, steps - 1)],
                           -1)
        B = x.shape[0]
        eps = eps_fn(x, jnp.full((B,), t, jnp.int32))
        k, ks = jax.random.split(k)
        return ddim_step(sched, eps, x, t, t_prev, eta=eta, key=ks), k

    x0, _ = jax.lax.fori_loop(0, steps, body, (x, kloop))
    return x0
