"""End-to-end diffusion serving pipeline (the paper's workload).

Batched request generation: noise -> iterative UNet denoising (DDPM or DDIM)
-> (for latent models) VAE decode.  The pipeline carries a
``PrecisionPolicy`` (``repro.core.precision``) selecting how UNet matmuls
execute — fp32, the W8A8 photonic path (C1), or W8A8 with analog-noise
injection — and every apply entry point takes a per-call ``policy=``
override so one pipeline can serve requests at different precisions (the
serving engine's per-request precision selection).  The legacy
``quant: bool`` is a deprecated alias for ``policy=PrecisionPolicy.w8a8()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy, resolve
from repro.diffusion import samplers
from repro.diffusion.schedule import Schedule, linear_schedule
from repro.models import autoencoder as AE
from repro.models import unet as U


@dataclasses.dataclass
class DiffusionPipeline:
    unet_cfg: U.UNetConfig
    unet_params: Any
    sched: Schedule
    vae_cfg: Optional[AE.VAEConfig] = None
    vae_params: Any = None
    policy: PrecisionPolicy = PrecisionPolicy.fp32()

    def __post_init__(self):
        # one-release shim: a bool / name in the policy slot still resolves
        if not isinstance(self.policy, PrecisionPolicy):
            self.policy = resolve(self.policy)

    @classmethod
    def init(cls, key, unet_cfg: U.UNetConfig,
             vae_cfg: Optional[AE.VAEConfig] = None,
             timesteps: Optional[int] = None, quant: Optional[bool] = None,
             policy: Optional[PrecisionPolicy] = None):
        """Build a pipeline with freshly initialized params.  ``policy``
        sets the default execution precision; ``quant=True`` is the
        deprecated boolean form of ``policy=PrecisionPolicy.w8a8()``."""
        k1, k2 = jax.random.split(key)
        unet_params = U.init_unet(k1, unet_cfg)
        vae_params = AE.init_vae(k2, vae_cfg) if vae_cfg else None
        sched = linear_schedule(timesteps or unet_cfg.timesteps)
        return cls(unet_cfg, unet_params, sched, vae_cfg, vae_params,
                   resolve(policy, quant))

    @property
    def quant(self) -> bool:
        """Deprecated view of the default policy (kept for one release)."""
        return self.policy.quantized

    def prequantize(self) -> 'DiffusionPipeline':
        """Serve-time calibration: pre-quantize every attention projection
        weight to a per-output-channel QTensor — exactly the weights the
        dynamic w8a8 path quantizes on the fly, with the same scale rule,
        so outputs agree to rounding (~1 LSB at tie boundaries) — and pin
        the policy's calibration mode."""
        from repro.core.quantization import quantize_per_channel
        proj = {'wq', 'wk', 'wv', 'wo', 'xq', 'xk', 'xv', 'xo'}

        def one(path, leaf):
            names = [str(getattr(k, 'key', '')) for k in path]
            if len(names) >= 2 and names[-1] == 'w' and names[-2] in proj:
                return quantize_per_channel(leaf)
            return leaf
        params = jax.tree_util.tree_map_with_path(one, self.unet_params)
        pol = self.policy if self.policy.quantized else PrecisionPolicy.w8a8()
        return dataclasses.replace(
            self, unet_params=params,
            policy=dataclasses.replace(pol, calibration='prequant'))

    def generate_deepcache(self, key, batch: int, steps: int = 50,
                           interval: int = 5, context=None,
                           policy: Optional[PrecisionPolicy] = None
                           ) -> jax.Array:
        """DDIM sampling with the DeepCache baseline ([21]): a full UNet
        pass every `interval` steps, shallow passes in between (deep
        features reused).  Python-level step loop (two jitted variants).
        With ``interval=1`` every step refreshes, so the output matches
        ``generate`` exactly.  ``policy`` overrides the pipeline's
        default precision for this call (the serving engine's cached
        fast path runs the same ``unet_apply_cached`` under per-request
        policies)."""
        from repro.diffusion.deepcache import unet_apply_cached
        import jax as _jax
        pol = resolve(policy) if policy is not None else self.policy
        sched = self.sched
        ts = samplers.ddim_timesteps(sched, steps)
        shape = self.sample_shape(batch)
        k0, key = jax.random.split(key)
        x = jax.random.normal(k0, shape)
        cache = None
        full = _jax.jit(lambda p, xx, tt, ctx: unet_apply_cached(
            p, self.unet_cfg, xx, tt, None, True, ctx, pol))
        shallow = _jax.jit(lambda p, xx, tt, c, ctx: unet_apply_cached(
            p, self.unet_cfg, xx, tt, c, False, ctx, pol))
        for i, t in enumerate(ts):
            tb = jnp.full((batch,), int(t), jnp.int32)
            if i % interval == 0 or cache is None:
                eps, cache = full(self.unet_params, x, tb, context)
            else:
                eps, _ = shallow(self.unet_params, x, tb, cache, context)
            t_prev = int(ts[i + 1]) if i + 1 < steps else -1
            x = samplers.ddim_step(sched, eps, x, int(t), t_prev)
        if self.vae_params is not None:
            x = AE.vae_decode(self.vae_params, self.vae_cfg, x)
        return x

    def _eps_fn(self, context=None, guidance: float = 0.0,
                policy: Optional[PrecisionPolicy] = None, noise_key=None):
        """Noise-prediction closure at a given precision.  For a noisy
        policy the per-evaluation key folds in the (first) timestep so
        the analog draw varies along the trajectory; an explicit
        ``noise_key`` re-anchors it (the engine threads a per-tick key)."""
        pol = resolve(policy) if policy is not None else self.policy
        base = None
        if pol.noisy:
            base = noise_key if noise_key is not None else \
                jax.random.PRNGKey(pol.noise_seed)

        def keyed(t, branch):
            if base is None:
                return None
            k = jax.random.fold_in(base, jnp.reshape(t, (-1,))[0])
            return jax.random.fold_in(k, branch)

        def eps(x, t):
            e = U.unet_apply(self.unet_params, self.unet_cfg, x, t,
                             context=context, policy=pol,
                             noise_key=keyed(t, 0))
            if guidance > 0.0 and context is not None:
                e_unc = U.unet_apply(self.unet_params, self.unet_cfg, x, t,
                                     context=None, policy=pol,
                                     noise_key=keyed(t, 1))
                e = e_unc + guidance * (e - e_unc)
            return e
        return eps

    def sample_shape(self, batch: int):
        c = self.unet_cfg
        return (batch, c.img_size, c.img_size, c.in_ch)

    def denoise_step(self, x: jax.Array, t: jax.Array, t_prev: jax.Array,
                     context=None, guidance: float = 0.0,
                     policy: Optional[PrecisionPolicy] = None,
                     noise_key=None) -> jax.Array:
        """One mixed-timestep DDIM step: `t` / `t_prev` are per-sample
        (B,) vectors, so a batch may hold samples at different denoising
        depths (the serving engine's per-tick kernel).  ``policy``
        overrides the pipeline default for this step."""
        eps = self._eps_fn(context, guidance, policy=policy,
                           noise_key=noise_key)(x, jnp.asarray(t, jnp.int32))
        return samplers.ddim_step(self.sched, eps, x, t, t_prev)

    def generate(self, key, batch: int, steps: int = 50,
                 sampler: str = 'ddim', context=None,
                 guidance: float = 0.0,
                 policy: Optional[PrecisionPolicy] = None) -> jax.Array:
        """Serve one batch of generation requests; returns images/latents.
        ``policy`` overrides the pipeline's default precision."""
        eps = self._eps_fn(context, guidance, policy=policy)
        shape = self.sample_shape(batch)
        if sampler == 'ddpm':
            z = samplers.ddpm_sample(self.sched, eps, shape, key)
        else:
            z = samplers.ddim_sample(self.sched, eps, shape, key,
                                     steps=steps)
        if self.vae_params is not None:
            z = AE.vae_decode(self.vae_params, self.vae_cfg, z)
        return z
