"""End-to-end diffusion serving pipeline (the paper's workload).

Batched request generation: noise -> iterative UNet denoising (DDPM or DDIM)
-> (for latent models) VAE decode.  ``quant=True`` serves the UNet through
the W8A8 path (C1) with classifier-free guidance optional for SDM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.diffusion import samplers
from repro.diffusion.schedule import Schedule, linear_schedule
from repro.models import autoencoder as AE
from repro.models import unet as U


@dataclasses.dataclass
class DiffusionPipeline:
    unet_cfg: U.UNetConfig
    unet_params: Any
    sched: Schedule
    vae_cfg: Optional[AE.VAEConfig] = None
    vae_params: Any = None
    quant: bool = False

    @classmethod
    def init(cls, key, unet_cfg: U.UNetConfig,
             vae_cfg: Optional[AE.VAEConfig] = None,
             timesteps: Optional[int] = None, quant: bool = False):
        k1, k2 = jax.random.split(key)
        unet_params = U.init_unet(k1, unet_cfg)
        vae_params = AE.init_vae(k2, vae_cfg) if vae_cfg else None
        sched = linear_schedule(timesteps or unet_cfg.timesteps)
        return cls(unet_cfg, unet_params, sched, vae_cfg, vae_params, quant)

    def generate_deepcache(self, key, batch: int, steps: int = 50,
                           interval: int = 5, context=None) -> jax.Array:
        """DDIM sampling with the DeepCache baseline ([21]): a full UNet
        pass every `interval` steps, shallow passes in between (deep
        features reused).  Python-level step loop (two jitted variants)."""
        from repro.diffusion.deepcache import unet_apply_cached
        import jax as _jax
        sched = self.sched
        ts = samplers.ddim_timesteps(sched, steps)
        shape = self.sample_shape(batch)
        k0, key = jax.random.split(key)
        x = jax.random.normal(k0, shape)
        cache = None
        full = _jax.jit(lambda p, xx, tt, ctx: unet_apply_cached(
            p, self.unet_cfg, xx, tt, None, True, ctx, self.quant))
        shallow = _jax.jit(lambda p, xx, tt, c, ctx: unet_apply_cached(
            p, self.unet_cfg, xx, tt, c, False, ctx, self.quant))
        for i, t in enumerate(ts):
            tb = jnp.full((batch,), int(t), jnp.int32)
            if i % interval == 0 or cache is None:
                eps, cache = full(self.unet_params, x, tb, context)
            else:
                eps, _ = shallow(self.unet_params, x, tb, cache, context)
            t_prev = int(ts[i + 1]) if i + 1 < steps else -1
            x = samplers.ddim_step(sched, eps, x, int(t), t_prev)
        if self.vae_params is not None:
            x = AE.vae_decode(self.vae_params, self.vae_cfg, x)
        return x

    def _eps_fn(self, context=None, guidance: float = 0.0):
        def eps(x, t):
            e = U.unet_apply(self.unet_params, self.unet_cfg, x, t,
                             context=context, quant=self.quant)
            if guidance > 0.0 and context is not None:
                e_unc = U.unet_apply(self.unet_params, self.unet_cfg, x, t,
                                     context=None, quant=self.quant)
                e = e_unc + guidance * (e - e_unc)
            return e
        return eps

    def sample_shape(self, batch: int):
        c = self.unet_cfg
        return (batch, c.img_size, c.img_size, c.in_ch)

    def denoise_step(self, x: jax.Array, t: jax.Array, t_prev: jax.Array,
                     context=None, guidance: float = 0.0) -> jax.Array:
        """One mixed-timestep DDIM step: `t` / `t_prev` are per-sample
        (B,) vectors, so a batch may hold samples at different denoising
        depths (the serving engine's per-tick kernel)."""
        eps = self._eps_fn(context, guidance)(x, jnp.asarray(t, jnp.int32))
        return samplers.ddim_step(self.sched, eps, x, t, t_prev)

    def generate(self, key, batch: int, steps: int = 50,
                 sampler: str = 'ddim', context=None,
                 guidance: float = 0.0) -> jax.Array:
        """Serve one batch of generation requests; returns images/latents."""
        eps = self._eps_fn(context, guidance)
        shape = self.sample_shape(batch)
        if sampler == 'ddpm':
            z = samplers.ddpm_sample(self.sched, eps, shape, key)
        else:
            z = samplers.ddim_sample(self.sched, eps, shape, key,
                                     steps=steps)
        if self.vae_params is not None:
            z = AE.vae_decode(self.vae_params, self.vae_cfg, z)
        return z
