"""Noise schedules and the forward (noising) process — paper Eq. 1."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    betas: jax.Array            # (T,)
    alphas: jax.Array           # (T,)
    alpha_bars: jax.Array       # (T,) cumulative products

    @property
    def T(self) -> int:
        return self.betas.shape[0]


def linear_schedule(T: int = 1000, beta_0: float = 1e-4,
                    beta_T: float = 0.02) -> Schedule:
    betas = jnp.linspace(beta_0, beta_T, T, dtype=jnp.float32)
    alphas = 1.0 - betas
    return Schedule(betas, alphas, jnp.cumprod(alphas))


def cosine_schedule(T: int = 1000, s: float = 0.008) -> Schedule:
    t = jnp.arange(T + 1, dtype=jnp.float32) / T
    f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2) ** 2
    alpha_bars = f / f[0]
    betas = jnp.clip(1 - alpha_bars[1:] / alpha_bars[:-1], 0, 0.999)
    alphas = 1.0 - betas
    return Schedule(betas, alphas, jnp.cumprod(alphas))


def q_sample(sched: Schedule, x0: jax.Array, t: jax.Array,
             noise: jax.Array) -> jax.Array:
    """Forward process (Eq. 1, closed form over t steps):
    x_t = sqrt(alpha_bar_t) x_0 + sqrt(1 - alpha_bar_t) eps."""
    ab = sched.alpha_bars[t].reshape((-1,) + (1,) * (x0.ndim - 1))
    return jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * noise


def ddpm_loss(unet_apply_fn, sched: Schedule, params, x0: jax.Array,
              key: jax.Array, context=None) -> jax.Array:
    """Simple epsilon-prediction objective (Ho et al.)."""
    kt, kn = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.randint(kt, (B,), 0, sched.T)
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    x_t = q_sample(sched, x0, t, noise)
    pred = unet_apply_fn(params, x_t, t, context)
    return jnp.mean(jnp.square(pred - noise))
