"""Prometheus-style metrics exposition and the periodic snapshot reporter.

``render_exposition`` turns a ``ServingMetrics`` ledger into the
Prometheus text format (``# HELP`` / ``# TYPE`` headers, counters,
gauges, and summary quantiles with ``_sum``/``_count``) so a scrape
endpoint — or a file the deployment tails — always has the live
counters, not just the end-of-run ``summary()`` dict.  Shed causes and
per-precision frontier aggregates are exposed as labels
(``...shed_total{reason="expired"}``,
``...frontier_mean_epb_picojoules{precision="w8a8"}``).

``SnapshotReporter`` is the in-run view: hand it to the engine
(``engine.reporter``) and every tick it checks a wall-clock interval,
emitting one compact progress line every ``interval_s`` seconds —
completed/submitted, requests/s, latency percentiles, queue state —
through any callable (``print``, ``logger.info``, a file append).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

#: Default metric namespace (Prometheus metric-name prefix).
NAMESPACE = 'repro_serving'


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats compact."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Lines:
    def __init__(self):
        self.out: List[str] = []

    def metric(self, name: str, mtype: str, help_text: str):
        self.out.append(f'# HELP {name} {help_text}')
        self.out.append(f'# TYPE {name} {mtype}')

    def sample(self, name: str, value, labels: str = ''):
        self.out.append(f'{name}{labels} {_fmt(value)}')

    def render(self) -> str:
        return '\n'.join(self.out) + '\n'


def render_exposition(metrics, active_slots: int = 0, queued: int = 0,
                      namespace: str = NAMESPACE) -> str:
    """Prometheus text exposition of a ``ServingMetrics`` ledger."""
    s = metrics.snapshot(active_slots=active_slots, queued=queued)
    L = _Lines()
    n = namespace

    counters = [
        ('submitted_total', s.submitted, 'Requests admitted to the queue'),
        ('completed_total', s.completed, 'Requests completed'),
        ('slo_violations_total', s.slo_violations,
         'Completed requests that missed their SLO'),
        ('ticks_total', s.ticks, 'Engine scheduler ticks executed'),
        ('unet_steps_total', s.unet_steps,
         'Slot-steps of UNet work executed'),
        ('full_steps_total', s.full_steps,
         'Slot-steps run as full UNet passes'),
        ('cached_steps_total', s.cached_steps,
         'Slot-steps run as shallow DeepCache passes'),
        ('early_exits_total', s.early_exits,
         'Requests drained by x0-convergence early exit'),
        ('steps_saved_total', s.steps_saved,
         'Requested-minus-executed denoise steps'),
        ('overlapped_decodes_total', s.overlapped_decodes,
         'VAE decodes overlapped with the next denoise tick'),
        ('resizes_total', s.resizes, 'Elastic mesh resizes survived'),
    ]
    for name, val, help_text in counters:
        full = f'{n}_{name}'
        L.metric(full, 'counter', help_text)
        L.sample(full, val)

    full = f'{n}_shed_total'
    L.metric(full, 'counter', 'Requests shed, by cause')
    if s.shed_by_reason:
        for reason in sorted(s.shed_by_reason):
            L.sample(full, s.shed_by_reason[reason],
                     labels=f'{{reason="{reason}"}}')
    else:
        L.sample(full, 0)

    full = f'{n}_energy_joules_total'
    L.metric(full, 'counter',
             'Simulated photonic energy attributed to completed requests')
    L.sample(full, s.total_energy_j)

    gauges = [
        ('active_slots', s.active_slots, 'Occupied engine slots'),
        ('queued', s.queued, 'Requests waiting in the admission queue'),
        ('queue_depth_peak', s.max_queue_depth,
         'Peak observed admission-queue depth'),
        ('devices', s.devices, 'Slot-shard device count'),
        ('requests_per_second', s.requests_per_s,
         'Completed-request throughput over the serving span'),
        ('cache_hit_rate', s.cache_hit_rate,
         'Fraction of slot-steps served by the shallow DeepCache pass'),
        ('warmup_seconds', s.warmup_s,
         'Wall seconds spent compiling in engine warmup'),
        ('first_tick_seconds', s.first_tick_s,
         'Engine construction to first served tick'),
    ]
    for name, val, help_text in gauges:
        full = f'{n}_{name}'
        L.metric(full, 'gauge', help_text)
        L.sample(full, val)

    for base, quantiles, sum_s, help_text in (
            ('latency_seconds',
             ((0.5, s.p50_latency_s), (0.95, s.p95_latency_s),
              (0.99, s.p99_latency_s)),
             metrics.latency_sum_s,
             'End-to-end request latency (submit to finish)'),
            ('queue_wait_seconds',
             ((0.5, s.p50_queue_wait_s), (0.99, s.p99_queue_wait_s)),
             metrics.queue_wait_sum_s,
             'Queue wait (submit to slot start)')):
        full = f'{n}_{base}'
        L.metric(full, 'summary', help_text)
        for q, v in quantiles:
            L.sample(full, v, labels=f'{{quantile="{q}"}}')
        L.sample(f'{full}_sum', sum_s)
        L.sample(f'{full}_count', s.completed)

    frontier = s.frontier
    if frontier:
        specs = (('frontier_completed', 'completed',
                  'Completed requests per precision policy'),
                 ('frontier_mean_epb_picojoules', 'mean_epb_pj',
                  'Mean energy-per-bit per precision policy'),
                 ('frontier_mean_energy_joules', 'mean_energy_j',
                  'Mean per-request energy per precision policy'))
        for name, key, help_text in specs:
            full = f'{n}_{name}'
            L.metric(full, 'gauge', help_text)
            for pol in sorted(frontier):
                L.sample(full, frontier[pol][key],
                         labels=f'{{precision="{pol}"}}')
    return L.render()


class SnapshotReporter:
    """Periodic in-run metrics line: call ``maybe_report(engine)`` (the
    engine does, once per tick, when installed as ``engine.reporter``)
    and a compact snapshot is emitted every ``interval_s`` wall seconds.
    The first call arms the interval without reporting, so an idle
    engine never logs at t=0."""

    def __init__(self, interval_s: float = 5.0,
                 emit: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if interval_s <= 0:
            raise ValueError('interval_s must be > 0')
        self.interval_s = interval_s
        self._emit = emit if emit is not None \
            else (lambda line: print(line, flush=True))
        self._clock = clock
        self._last: Optional[float] = None
        self.reports = 0

    def maybe_report(self, engine=None, metrics=None, active_slots: int = 0,
                     queued: int = 0, force: bool = False) -> Optional[str]:
        t = self._clock()
        if self._last is None:
            self._last = t
            if not force:
                return None
        if not force and t - self._last < self.interval_s:
            return None
        self._last = t
        if engine is not None:
            metrics = engine.metrics
            active_slots = engine.active_count
            queued = len(engine.queue)
        s = metrics.snapshot(active_slots=active_slots, queued=queued)
        line = (f'completed={s.completed}/{s.submitted} '
                f'rps={s.requests_per_s:.2f} '
                f'p50={s.p50_latency_s * 1e3:.0f}ms '
                f'p95={s.p95_latency_s * 1e3:.0f}ms '
                f'shed={s.shed} active={s.active_slots} '
                f'queued={s.queued} ticks={s.ticks}')
        self._emit(line)
        self.reports += 1
        return line
