"""Trace exporters: JSONL structured event log and Chrome ``trace_event``.

Both exporters are strict-JSON by construction: ``sanitize`` rewrites
every non-finite float (NaN/inf — e.g. an unprobed PSNR mean) to
``null`` before serialization, and the writers pass ``allow_nan=False``
so a bare ``NaN`` token can never reach disk.

JSONL log — one event object per line, the flat ``TraceEvent.to_dict``
shape.  Grep-able, tail-able, trivially re-loadable (``read_jsonl``).

Chrome trace — the ``{"traceEvents": [...]}`` JSON the ``chrome://
tracing`` / Perfetto UI loads.  The serving run renders as one process
(pid 0) with one thread lane per engine slot plus two fixed lanes:

  * tid 0 ``scheduler`` — tick/step spans and engine-global events
    (warmup, AOT lowering, elastic resize, straggler flags);
  * tid 1..slots ``slot i (dev d)`` — per-request service spans and
    decode events, one lane per slot of the engine buffer;
  * tid 999 ``queue`` — submit/shed/expire instants.

Timestamps convert from serving-clock seconds to the microseconds the
format requires; counter events (occupancy) become ``ph='C'`` series
Perfetto draws as a stacked area.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Union

from repro.obs.tracer import TraceEvent, Tracer

#: Fixed Chrome-trace thread lanes (slots are 1..N between them).
SCHEDULER_TID = 0
QUEUE_TID = 999


def sanitize(obj: Any) -> Any:
    """Recursively replace non-finite floats with None (strict JSON)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def _events(source: Union[Tracer, Iterable[TraceEvent]]) -> List[TraceEvent]:
    return list(source.events if isinstance(source, Tracer) else source)


# -- JSONL -------------------------------------------------------------------
def write_jsonl(source: Union[Tracer, Iterable[TraceEvent]],
                path: str) -> int:
    """Write one JSON object per event line; returns the event count."""
    events = _events(source)
    with open(path, 'w') as f:
        for e in events:
            f.write(json.dumps(sanitize(e.to_dict()), allow_nan=False,
                               sort_keys=True))
            f.write('\n')
    return len(events)


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL event log back into a list of dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# -- Chrome trace ------------------------------------------------------------
def _tid(e: TraceEvent) -> int:
    if e.slot is not None:
        return 1 + e.slot
    if e.cat == 'queue':
        return QUEUE_TID
    return SCHEDULER_TID


def chrome_trace(source: Union[Tracer, Iterable[TraceEvent]],
                 pid: int = 0) -> Dict[str, Any]:
    """Render events as a Chrome ``trace_event`` document (dict)."""
    events = _events(source)
    rows: List[Dict[str, Any]] = [{
        'name': 'process_name', 'ph': 'M', 'pid': pid, 'tid': 0,
        'args': {'name': 'serving engine'}}]
    lanes: Dict[int, str] = {SCHEDULER_TID: 'scheduler'}
    for e in events:
        tid = _tid(e)
        if tid not in lanes:
            if tid == QUEUE_TID:
                lanes[tid] = 'queue'
            else:
                lanes[tid] = f'slot {tid - 1}' + (
                    f' (dev {e.device})' if e.device is not None else '')
        row: Dict[str, Any] = {
            'name': e.name, 'cat': e.cat, 'ph': e.ph,
            'ts': e.ts * 1e6, 'pid': pid, 'tid': tid}
        if e.ph == 'X':
            row['dur'] = e.dur * 1e6
        if e.ph == 'i':
            row['s'] = 't'          # instant scope: thread
        args = dict(e.args)
        for k in ('rid', 'device', 'tick'):
            v = getattr(e, k)
            if v is not None:
                args[k] = v
        if args:
            row['args'] = args
        rows.append(row)
    for tid, name in sorted(lanes.items()):
        rows.append({'name': 'thread_name', 'ph': 'M', 'pid': pid,
                     'tid': tid, 'args': {'name': name}})
    return sanitize({'traceEvents': rows,
                     'displayTimeUnit': 'ms'})


def write_chrome_trace(source: Union[Tracer, Iterable[TraceEvent]],
                       path: str, pid: int = 0) -> int:
    """Write the Chrome trace JSON; returns the trace-event row count."""
    doc = chrome_trace(source, pid=pid)
    with open(path, 'w') as f:
        json.dump(doc, f, allow_nan=False)
        f.write('\n')
    return len(doc['traceEvents'])
