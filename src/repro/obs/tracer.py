"""Span/event tracer for the serving stack.

One ``Tracer`` records a serving run as a flat list of ``TraceEvent``
rows — instants (a request was submitted, a shed happened, a straggler
was flagged), complete spans (a step dispatch, a whole tick, warmup, a
request's submit-to-finish lifetime) and counters (occupancy per tick).
Timestamps ride the *serving clock*: ``now()`` is monotonic seconds
since the tracer's origin (``time.perf_counter`` based), and
``set_origin`` lets the engine pin that origin to its replay wall-clock
zero so trace timestamps and ``GenerationResult`` timing fields agree
exactly.  Events recorded with an explicit ``ts`` (e.g. a request span
stamped from the result's own submit/finish times) reconcile with
``ServingMetrics`` by construction.

Tracing is ZERO-COST when disabled: the default engine tracer is the
module singleton ``NULL_TRACER`` whose ``enabled`` flag is False — hot
paths guard on that flag and never build event objects, and every
recording method is a no-op.  An enabled tracer appends one small
dataclass per event; exporters (``repro.obs.export``) turn the list into
a JSONL structured log or a Chrome/Perfetto ``trace_event`` timeline.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional

#: Event categories used by the serving instrumentation.  Free-form —
#: exporters pass them through — but the engine sticks to this set.
CATEGORIES = ('queue', 'request', 'tick', 'decode', 'engine')


@dataclasses.dataclass
class TraceEvent:
    """One trace row.  ``ph`` follows the Chrome trace_event phases the
    exporter maps onto: ``'i'`` instant, ``'X'`` complete (has ``dur``),
    ``'C'`` counter (values live in ``args``)."""
    name: str
    cat: str
    ph: str
    ts: float                       # serving-clock seconds
    dur: float = 0.0                # seconds ('X' events only)
    rid: Optional[int] = None       # request id, when request-scoped
    slot: Optional[int] = None      # engine slot index, when slot-scoped
    device: Optional[int] = None    # mesh device index, when known
    tick: Optional[int] = None      # engine tick index, when tick-scoped
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        """Flat dict for the JSONL log (None-valued ids dropped)."""
        d = {'name': self.name, 'cat': self.cat, 'ph': self.ph,
             'ts': self.ts}
        if self.ph == 'X':
            d['dur'] = self.dur
        for k in ('rid', 'slot', 'device', 'tick'):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.args:
            d['args'] = self.args
        return d


class Tracer:
    """Collects ``TraceEvent`` rows on a monotonic serving clock."""

    enabled = True

    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: List[TraceEvent] = []

    # -- clock --------------------------------------------------------------
    def now(self) -> float:
        """Seconds since the trace origin (monotonic)."""
        return time.perf_counter() - self._t0

    def set_origin(self, perf_counter_t0: float) -> None:
        """Pin the trace origin to a ``time.perf_counter()`` reading —
        the engine passes its replay wall-clock zero so trace timestamps
        live on the same serving clock as request timing fields."""
        self._t0 = perf_counter_t0

    # -- recording ----------------------------------------------------------
    def instant(self, name: str, cat: str = 'engine',
                ts: Optional[float] = None, rid: Optional[int] = None,
                slot: Optional[int] = None, device: Optional[int] = None,
                tick: Optional[int] = None, **args) -> TraceEvent:
        e = TraceEvent(name=name, cat=cat, ph='i',
                       ts=self.now() if ts is None else ts,
                       rid=rid, slot=slot, device=device, tick=tick,
                       args=args)
        self.events.append(e)
        return e

    def complete(self, name: str, t0: float, t1: float,
                 cat: str = 'engine', rid: Optional[int] = None,
                 slot: Optional[int] = None, device: Optional[int] = None,
                 tick: Optional[int] = None, **args) -> TraceEvent:
        """A finished span ``[t0, t1]`` on the serving clock."""
        e = TraceEvent(name=name, cat=cat, ph='X', ts=t0,
                       dur=max(0.0, t1 - t0), rid=rid, slot=slot,
                       device=device, tick=tick, args=args)
        self.events.append(e)
        return e

    def counter(self, name: str, cat: str = 'engine',
                ts: Optional[float] = None, tick: Optional[int] = None,
                **values) -> TraceEvent:
        """A counter sample (numeric series, e.g. occupancy per tick)."""
        e = TraceEvent(name=name, cat=cat, ph='C',
                       ts=self.now() if ts is None else ts,
                       tick=tick, args=values)
        self.events.append(e)
        return e

    @contextlib.contextmanager
    def region(self, name: str, cat: str = 'engine',
               **args) -> Iterator[None]:
        """Span context manager on the tracer clock."""
        t0 = self.now()
        try:
            yield
        finally:
            self.complete(name, t0, self.now(), cat=cat, **args)

    # -- reading ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def select(self, name: Optional[str] = None, cat: Optional[str] = None,
               ph: Optional[str] = None) -> List[TraceEvent]:
        """Events filtered by name/category/phase (None = any)."""
        return [e for e in self.events
                if (name is None or e.name == name)
                and (cat is None or e.cat == cat)
                and (ph is None or e.ph == ph)]

    def spans(self, name: Optional[str] = None,
              cat: Optional[str] = None) -> List[TraceEvent]:
        """Complete ('X') events, optionally filtered."""
        return self.select(name=name, cat=cat, ph='X')


class NullTracer(Tracer):
    """No-op tracer: the zero-cost default.  ``enabled`` is False, so
    instrumented hot paths skip event construction entirely; the
    recording methods are inert for call sites that don't guard."""

    enabled = False

    def __init__(self):
        super().__init__()

    def instant(self, *a, **k) -> None:          # type: ignore[override]
        return None

    def complete(self, *a, **k) -> None:         # type: ignore[override]
        return None

    def counter(self, *a, **k) -> None:          # type: ignore[override]
        return None

    @contextlib.contextmanager
    def region(self, *a, **k) -> Iterator[None]:
        yield


#: Shared no-op singleton — the engine's default ``tracer``.
NULL_TRACER = NullTracer()
