"""Serving observability: per-request tracing, structured event logs,
Chrome-trace export, and Prometheus-style metrics exposition.

Quickstart::

    from repro.obs import Tracer, SnapshotReporter, write_chrome_trace
    tracer = Tracer()
    engine = ContinuousBatchingEngine(pipe, slots=4, tracer=tracer)
    engine.warmup()
    engine.replay(trace)
    write_chrome_trace(tracer, 'trace.json')      # chrome://tracing
    write_jsonl(tracer, 'events.jsonl')           # structured log
    print(render_exposition(engine.metrics))      # Prometheus text

Tracing is zero-cost when disabled: the engine default is the no-op
``NULL_TRACER`` (``enabled == False``) and every hot-path hook guards on
that flag, so an untraced engine builds no event objects at all.
"""
from repro.obs.export import (chrome_trace, read_jsonl, sanitize,
                              write_chrome_trace, write_jsonl)
from repro.obs.prom import NAMESPACE, SnapshotReporter, render_exposition
from repro.obs.tracer import (CATEGORIES, NULL_TRACER, NullTracer,
                              TraceEvent, Tracer)

__all__ = [
    'Tracer', 'NullTracer', 'NULL_TRACER', 'TraceEvent', 'CATEGORIES',
    'chrome_trace', 'write_chrome_trace', 'write_jsonl', 'read_jsonl',
    'sanitize', 'render_exposition', 'SnapshotReporter', 'NAMESPACE',
]
