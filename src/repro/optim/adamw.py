"""AdamW + schedules + gradient utilities (pure JAX, optax-free).

Optimizer state mirrors the parameter pytree (m, v copies), so the same
PartitionSpecs shard it — the FSDP axis automatically gives ZeRO-style
optimizer-state sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # 'bfloat16' halves optimizer-state HBM (ZeRO-style memory saver for
    # >100B archs); update math still runs in f32.
    moment_dtype: str = 'float32'


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * \
        0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_adamw(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=moment_dtype), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                      v=zeros(params))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * factor, grads), norm


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(mdt), v2.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
