"""Gradient accumulation: split the global batch into microbatches inside
one jitted step (`lax.scan` over microbatches, so activation memory is that
of ONE microbatch while the optimizer sees the full-batch gradient).

This is the memory-side knob complementing the remat policy: at the
1000-node scale it lets the same global batch run on fewer/healthier hosts
after an elastic re-mesh (the per-device microbatch shrinks instead of the
global batch changing, keeping training curves comparable).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update


def build_accum_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                           accum_steps: int,
                           real_vocab: Optional[int] = None,
                           dtype=jnp.bfloat16) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).
    batch dims must be divisible by accum_steps; microbatches are scanned
    and gradients averaged before one optimizer update."""

    def loss_fn(p, mb):
        if cfg.family == 'encdec':
            return ED.encdec_loss(p, cfg, mb['frames'], mb['tokens'],
                                  mb['labels'], dtype=dtype,
                                  real_vocab=real_vocab)
        return T.lm_loss(p, cfg, mb['tokens'], mb['labels'], dtype=dtype,
                         real_vocab=real_vocab)

    def train_step(params, opt_state, batch):
        B = batch['tokens'].shape[0]
        assert B % accum_steps == 0, (B, accum_steps)
        mb_size = B // accum_steps
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((accum_steps, mb_size) + x.shape[1:]),
            batch)

        def body(carry, mb):
            g_acc, l_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                g_acc, grads)
            return (g_acc, l_acc + loss / accum_steps), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        new_params, new_opt, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        return new_params, new_opt, {'loss': loss, 'grad_norm': gnorm}

    return train_step
