"""Pallas TPU kernel: fused GroupNorm + swish (DiffLight C5).

The paper's Residual unit chains a broadband-MR normalization stage directly
into the SOA swish stage — one optical pass, no intermediate digitization.
The TPU analogue is a single VMEM pass: each program normalizes one
(batch, group) slab (H, W, C/g) and applies x*sigmoid(x) before writing back,
eliminating the intermediate HBM round-trip of norm -> act.

Grid: (N, groups).  Slab shape (H, W, C/g) must fit VMEM (UNet feature maps
at <=64x64 spatial easily do; ops.py asserts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, bias_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)               # (H, W, cg)
    mu = jnp.mean(x)
    var = jnp.mean(jnp.square(x - mu))
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale_ref[0, 0, 0] + bias_ref[0, 0, 0]   # (cg,) broadcast
    o_ref[0] = (y * jax.nn.sigmoid(y)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('groups', 'eps', 'interpret'))
def fused_gn_swish_kernel(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                          groups: int = 32, eps: float = 1e-5,
                          interpret: bool = False) -> jax.Array:
    """x (N, H, W, C), scale/bias (C,).  C % groups == 0."""
    N, H, W, C = x.shape
    assert C % groups == 0, (C, groups)
    cg = C // groups
    scale4 = scale.reshape(1, 1, 1, C).astype(jnp.float32)
    bias4 = bias.reshape(1, 1, 1, C).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(N, groups),
        in_specs=[
            pl.BlockSpec((1, H, W, cg), lambda n, g: (n, 0, 0, g)),
            pl.BlockSpec((1, 1, 1, cg), lambda n, g: (0, 0, 0, g)),
            pl.BlockSpec((1, 1, 1, cg), lambda n, g: (0, 0, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, H, W, cg), lambda n, g: (n, 0, 0, g)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale4, bias4)
