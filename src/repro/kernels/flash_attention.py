"""Pallas TPU kernel: flash attention with the paper's streaming LSE softmax.

DiffLight (C2) digitizes attention scores as they stream out of the MR banks
and *concurrently* tracks gamma_max with a comparator, accumulating
ln-sum-exp via LUTs (Eq. 4).  Blockwise in VMEM, that pipeline is exactly the
online-softmax recurrence:

    m'   = max(m, max_j s_j)            # comparator
    l'   = l * e^(m-m') + sum_j e^(s_j - m')   # LUT exp + accumulate
    acc' = acc * e^(m-m') + P V_blk     # MR bank no.7 of the attention head
    out  = acc / l                      # ops 2+3 of Eq. 4 (ln + subtract)

Grid: (batch*heads, nq, nk) with the KV loop innermost; (m, l, acc) live in
VMEM scratch across KV steps.  Causal blocks beyond the diagonal are skipped
(grid-level work elision — the photonic analogue is not lighting idle banks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, nk: int, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked blocks (k block strictly after q block)
        pl.when(ki * bk <= qi * bq + bq - 1)(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=('causal', 'scale', 'bq', 'bk',
                                    'interpret'))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = False,
                           scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (BH, S, d), k/v (BH, T, d) -> (BH, S, d).  S % bq == 0, T % bk == 0
    (ops.py pads and masks)."""
    BH, S, d = q.shape
    T = k.shape[1]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    if scale is None:
        scale = d ** -0.5
    nq, nk = S // bq, T // bk
    grid = (BH, nq, nk)
    kern = functools.partial(_kernel, scale=scale, causal=causal, nk=nk,
                             bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
