"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, activation quantization, head folding,
and the CPU fallback: on a non-TPU backend the wrappers run the kernels in
``interpret=True`` mode (bit-equivalent Python execution) or, when
``REPRO_KERNELS=xla``, the pure-jnp oracle — the latter is what the
distributed dry-run lowers so roofline terms reflect the XLA path.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize, quantize_per_channel
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.fused_gn_swish import fused_gn_swish_kernel
from repro.kernels.w8a8_matmul import w8a8_matmul_kernel


def _mode() -> str:
    """'pallas' on TPU, 'interpret' on CPU, or forced via REPRO_KERNELS."""
    forced = os.environ.get('REPRO_KERNELS')
    if forced:
        return forced
    return 'pallas' if jax.default_backend() == 'tpu' else 'xla'


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# W8A8 matmul
# ---------------------------------------------------------------------------

def w8a8_matmul(x: jax.Array, w, *, mode: str | None = None) -> jax.Array:
    """x (..., K) float, w (K, N) float or pre-quantized QTensor
    -> (..., N) f32.

    Quantizes activations per row (dynamic); weights are quantized per
    output channel here unless already a QTensor (serve-time prequant).
    """
    from repro.core.quantization import QTensor
    mode = mode or _mode()
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    xq = quantize(x2, axis=(1,))
    wq = w if isinstance(w, QTensor) else quantize_per_channel(w)
    if mode == 'xla':
        out = _ref.w8a8_matmul_ref(xq.q, xq.scale, wq.q,
                                   wq.scale.reshape(1, -1))
    else:
        M = x2.shape[0]
        bm = min(128, max(8, M))
        q_p = _pad_to(_pad_to(xq.q, 0, bm), 1, 128)
        s_p = _pad_to(xq.scale, 0, bm)
        wq_p = _pad_to(_pad_to(wq.q, 0, 128), 1, 128)
        ws_p = _pad_to(wq.scale.reshape(1, -1), 1, 128)
        out = w8a8_matmul_kernel(
            q_p, s_p, wq_p, ws_p, bm=bm,
            interpret=(mode == 'interpret'))[:M, :N]
    return out.reshape(*lead, N)


# ---------------------------------------------------------------------------
# Flash attention (streaming LSE softmax)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, scale: float | None = None,
                    mode: str | None = None) -> jax.Array:
    """q (B, H, S, d), k/v (B, H, T, d) -> (B, H, S, d)."""
    mode = mode or _mode()
    B, H, S, d = q.shape
    T = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    if mode == 'xla':
        from repro.core.lse_softmax import streaming_attention_ref
        return streaming_attention_ref(q, k, v, causal=causal, scale=scale)
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    bq = min(128, S)
    bk = min(128, T)
    q_p = _pad_to(qf, 1, bq)
    k_p = _pad_to(kf, 1, bk)
    v_p = _pad_to(vf, 1, bk)
    if k_p.shape[1] != T:
        # padded KV rows must not contribute: causal masking handles q-side
        # padding; for kv-side padding use an additive -inf via a huge
        # negative key? Simplest correct: mask by zero-value + min-score:
        # set padded K rows to produce -inf scores by making them equal to
        # a large negative multiple of q... safer: fall back to masking via
        # explicit score mask is not in-kernel; instead pad K with -1e4 *
        # unit vectors is fragile -> use oracle path for ragged T.
        if not causal:
            from repro.core.lse_softmax import streaming_attention_ref
            return streaming_attention_ref(q, k, v, causal=False, scale=scale)
    out = flash_attention_kernel(
        q_p, k_p, v_p, causal=causal, scale=scale, bq=bq, bk=bk,
        interpret=(mode == 'interpret'))
    return out[:, :S, :].reshape(B, H, S, d)


# ---------------------------------------------------------------------------
# Fused GroupNorm + swish
# ---------------------------------------------------------------------------

def fused_gn_swish(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                   groups: int = 32, mode: str | None = None) -> jax.Array:
    mode = mode or _mode()
    C = x.shape[-1]
    g = min(groups, C)
    while C % g:
        g -= 1
    if mode == 'xla':
        return _ref.gn_swish_ref(x, scale, bias, groups=g)
    return fused_gn_swish_kernel(x, scale, bias, groups=g,
                                 interpret=(mode == 'interpret'))
