"""Pallas TPU kernel: W8A8 GEMM (DiffLight C1, MR-bank MAC datapath).

Photonic mapping -> TPU mapping:
  * MR bank array (K rows x N cols)        -> one MXU-aligned VMEM tile
  * activation MR bank + weight MR bank    -> int8 x int8 systolic matmul
  * balanced photodetector accumulation    -> int32 accumulator (scratch)
  * MR transmission calibration (scales)   -> per-row activation scale and
                                              per-column weight scale epilogue
  * VCSEL / DAC sharing (operand reuse)    -> grid ordering keeps the weight
    tile resident across the M dimension (weight-stationary: the "DAC
    sharing" energy trick becomes HBM-traffic reuse)

Grid: (M/bm, N/bn, K/bk), K innermost so the int32 accumulator lives in VMEM
scratch across the K loop; the f32 epilogue (scale multiply) runs once at the
final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        # rescale: out = acc * x_scale[m] * w_scale[n]
        out_ref[...] = (acc_ref[...].astype(jnp.float32)
                        * xs_ref[...] * ws_ref[...])


@functools.partial(jax.jit, static_argnames=('bm', 'bn', 'bk', 'interpret'))
def w8a8_matmul_kernel(xq: jax.Array, x_scale: jax.Array, wq: jax.Array,
                       w_scale: jax.Array, *, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                       interpret: bool = False) -> jax.Array:
    """xq (M, K) int8, x_scale (M, 1) f32, wq (K, N) int8, w_scale (1, N) f32
    -> (M, N) f32.  M, N, K must be multiples of the block sizes (ops.py
    pads)."""
    M, K = xq.shape
    _, N = wq.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),   # xq
            pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)),    # x_scale
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),   # wq
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),    # w_scale
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, x_scale, wq, w_scale)
