"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are swept against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def w8a8_matmul_ref(xq: jax.Array, x_scale: jax.Array, wq: jax.Array,
                    w_scale: jax.Array) -> jax.Array:
    """Same contract as w8a8_matmul_kernel: int8 operands, f32 scales."""
    acc = jax.lax.dot_general(
        xq, wq, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * x_scale * w_scale


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, scale: float | None = None
                  ) -> jax.Array:
    """Naive full-materialization attention (BH, S, d)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = jnp.einsum('bsd,btd->bst', q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum('bst,btd->bsd', p,
                      v.astype(jnp.float32)).astype(q.dtype)


def gn_swish_ref(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                 groups: int = 32, eps: float = 1e-5) -> jax.Array:
    N, H, W, C = x.shape
    cg = C // groups
    xf = x.astype(jnp.float32).reshape(N, H, W, groups, cg)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(N, H, W, C)
    y = y * scale + bias
    return (y * jax.nn.sigmoid(y)).astype(x.dtype)
