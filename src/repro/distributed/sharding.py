"""Sharding rules: parameter / activation / cache PartitionSpecs.

2-D weight sharding (MaxText-style "fsdp x tensor"): matmul weights shard
their contracting (d_model-ish) dim over ``data`` (FSDP — XLA all-gathers a
layer's weights just before use, inside the scanned layer body, which
overlaps with the previous layer's compute) and their output dim over
``model`` (tensor parallelism).  Expert dims shard over ``model`` (expert
parallelism).  The ``pod`` axis is pure DP: parameters are replicated across
pods and gradients all-reduce over it.

Rules are path-regex based with a divisibility fallback: any axis that does
not divide the dimension is dropped (replicated) rather than failing — the
dry-run prints what was dropped so sharding gaps are visible, not silent.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

# (path regex, spec for the TRAILING dims of the leaf)
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r'embed/table$', ('model', 'data')),
    (r'lm_head/w(/q|/scale)?$', ('data', 'model')),
    # projections with output dim sharded over tensor axis
    (r'(wq|wk|wv|xq|xk|xv|up|gate|in_z|in_xbc|in_dt|w_dkv|w_kpe|w_uk|w_uv)'
     r'/w(/q|/scale)?$', ('data', 'model')),
    # projections back to d_model: input dim over tensor axis
    (r'(wo|xo|down|out_proj)/w(/q|/scale)?$', ('model', 'data')),
    (r'router/w$', ('data', None)),
    # MoE expert banks: expert-parallel over 'model', FSDP over 'data'
    (r'(w_gate|w_up)(/q|/scale)?$', ('model', 'data', None)),
    (r'w_down(/q|/scale)?$', ('model', None, 'data')),
    # mamba per-channel params
    (r'conv_w$', (None, 'model')),
    (r'conv_b$', ('model',)),
    (r'(A_log|D|dt_bias)$', ('model',)),
    # biases / norms: replicated
    (r'/b$', (None,)),
    (r'(scale|bias)$', (None,)),
)


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, 'key'):
            parts.append(str(e.key))
        elif hasattr(e, 'idx'):
            parts.append(str(e.idx))
    return '/'.join(parts)


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return int(mesh.shape.get(name, 1))


def _fit_spec(spec: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              mesh: Mesh, dropped: list, path: str) -> P:
    """Left-pad with None to ndim; drop axes that don't divide."""
    full = (None,) * (len(shape) - len(spec)) + tuple(spec)
    full = full[:len(shape)]
    out = []
    for dim, ax in zip(shape, full):
        if ax is not None and ax in mesh.axis_names and \
                dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            if ax is not None:
                dropped.append((path, dim, ax))
            out.append(None)
    return P(*out)


def param_pspecs(params: Any, mesh: Mesh, verbose: bool = False,
                 model_axis_tp: bool = True) -> Any:
    """PartitionSpec pytree matching ``params``.

    ``model_axis_tp=False`` (EP-only mode): non-expert weights drop the
    'model' axis and shard FSDP-only — expert banks, embedding and lm_head
    keep it."""
    dropped: list = []
    keep_model = (r'(w_gate|w_up)(/q|/scale)?$', r'w_down(/q|/scale)?$',
                  r'embed/table$', r'lm_head/w(/q|/scale)?$')

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = np.shape(leaf) if not hasattr(leaf, 'shape') else leaf.shape
        if len(shape) == 0:
            return P()
        for pat, spec in PARAM_RULES:
            if re.search(pat, ps):
                if not model_axis_tp and not any(
                        re.search(k, ps) for k in keep_model):
                    spec = tuple(None if a == 'model' else a for a in spec)
                return _fit_spec(spec, shape, mesh, dropped, ps)
        return P(*([None] * len(shape)))

    specs = jax.tree_util.tree_map_with_path(spec_for, params)
    if verbose and dropped:
        for path, dim, ax in dropped[:20]:
            print(f'[sharding] dropped axis {ax!r} on {path} (dim {dim})')
    return specs


def dp_spec(mesh: Mesh, batch: int):
    """The data-parallel sharding of a batch dim (pod+data), or None when
    the batch is too small to shard (long-context decode)."""
    from repro.launch.mesh import dp_axes
    axes = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if 'data' in axes and batch % mesh.shape['data'] == 0:
        return 'data'
    return None


def batch_pspecs(mesh: Mesh, batch: int, ndim: int = 2) -> P:
    """Token/label arrays (B, S, ...)."""
    return P(dp_spec(mesh, batch), *([None] * (ndim - 1)))


def cache_pspecs(cache: Any, mesh: Mesh, batch: int,
                 shard_seq_when_unbatched: bool = True,
                 mla_cache_seq: bool = False) -> Any:
    """KV / state caches.  Layout per leaf (leading scan-layer dim, then
    batch):
      attn k/v      (L, B, T, Hkv, hd) -> (None, dp, seq?, 'model', None)
      mla  c_kv     (L, B, T, rank)    -> (None, dp, seq?, None)
      mamba conv    (L, B, K-1, cd)    -> (None, dp, None, 'model')
      mamba state   (L, B, H, P, N)    -> (None, dp, 'model', None, None)
    When the batch doesn't shard (B=1 long-context), the cache sequence dim
    shards over 'data' instead (sequence parallelism for the cache).
    """
    dp = dp_spec(mesh, batch)
    seq_ax = 'data' if (dp is None and shard_seq_when_unbatched) else None

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if re.search(r'(k|v|c_kv|k_pe)$', ps) and nd >= 4:
            # (L, B, T, ...) attention-ish
            dims = [None, dp, seq_ax]
            if re.search(r'(k|v)$', ps) and nd == 5:
                # heads over 'model' when they divide; otherwise shard the
                # SEQUENCE dim over 'model' (keeps the per-chip cache under
                # the HBM budget for 36/56/28-head archs — DESIGN.md §8)
                if shape[3] % _axis_size(mesh, 'model') == 0:
                    dims += ['model', None]
                elif seq_ax is None and \
                        shape[2] % _axis_size(mesh, 'model') == 0:
                    dims = [None, dp, 'model', None, None]
                else:
                    dims += [None, None]
            else:
                # MLA compressed cache (L, B, T, rank): optionally shard the
                # sequence dim over 'model' (it has no head dim to shard)
                if mla_cache_seq and seq_ax is None:
                    dims = [None, dp, 'model']
                dims += [None] * (nd - len(dims))
            return _fit_and_check(dims[:nd], shape)
        if re.search(r'conv$', ps):
            return _fit_and_check([None, dp, None, 'model'][:nd], shape)
        if re.search(r'state$', ps):
            return _fit_and_check([None, dp, 'model', None, None][:nd],
                                  shape)
        return P(*([None] * nd))

    def _fit_and_check(dims, shape):
        out = []
        for d, ax in zip(shape, dims):
            if ax is None:
                out.append(None)
            elif isinstance(ax, tuple):
                size = int(np.prod([mesh.shape[a] for a in ax]))
                out.append(ax if d % size == 0 else None)
            else:
                out.append(ax if d % _axis_size(mesh, ax) == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh, or None outside any mesh context.

    Tries, in order: the explicit-sharding abstract mesh (newer JAX),
    the legacy ``with mesh:`` thread-resource env via the public
    ``jax.interpreters.pxla`` spelling, and finally the private
    ``jax._src.mesh`` module (version-guarded last resort).  Each probe
    is guarded separately so a missing API on one JAX release never
    hides a context visible through another — the failure mode that
    silently turned ``shard_hint`` into a no-op."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            return mesh
    except AttributeError:
        pass
    try:
        from jax.interpreters import pxla
        phys = pxla.thread_resources.env.physical_mesh
        if phys.axis_names:
            return phys
    except (ImportError, AttributeError):
        pass
    try:                                       # pragma: no cover
        from jax._src import mesh as _mesh_lib
        phys = _mesh_lib.thread_resources.env.physical_mesh
        if phys.axis_names:
            return phys
    except Exception:
        pass
    return None


def shard_hint(x, *spec, mesh: Optional[Mesh] = None):
    """``with_sharding_constraint`` that degrades gracefully: outside a mesh
    context (CPU smoke tests) it is the identity; axes that are absent from
    the mesh or don't divide the dim are dropped.  ``spec`` entries may be
    axis names, tuples of axis names, or the sentinel ``'dp'`` (all
    data-parallel axes present in the mesh).  ``mesh`` pins the mesh
    explicitly (the sharded serving engine passes its own); by default the
    ambient context is discovered via ``current_mesh``."""
    if mesh is None:
        mesh = current_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)
    out = []
    for dim, ax in zip(x.shape, spec):
        if ax == 'dp':
            ax = tuple(a for a in ('pod', 'data') if a in names)
            if not ax:
                out.append(None)
                continue
            size = int(np.prod([mesh.shape[a] for a in ax]))
            if dim % size == 0:
                out.append(ax if len(ax) > 1 else ax[0])
            elif 'data' in ax and dim % mesh.shape['data'] == 0:
                out.append('data')
            else:
                out.append(None)
            continue
        if isinstance(ax, str) and ax in names and \
                dim % int(mesh.shape[ax]) == 0:
            out.append(ax)
        else:
            out.append(None)
    out += [None] * (x.ndim - len(out))
    # a concrete NamedSharding, not a bare PartitionSpec: the constraint
    # then works outside any `with mesh:` context (the sharded serving
    # engine passes its mesh explicitly from plain eager code)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def named(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
