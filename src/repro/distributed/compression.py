"""Gradient compression for cross-pod data parallelism.

At 1000+ nodes the cross-pod (DCI) all-reduce is the scarcest bandwidth.
We provide int8 symmetric gradient compression with **error feedback**
(residual carried to the next step, so compression error does not bias the
optimizer — Karimireddy et al. 2019): the pod-local reduction runs at full
precision, only the cross-pod exchange is quantized.

Usage inside a train step:
    g_q, new_residual = compress_with_feedback(grads, residual)
    g_sync = psum_over_pods(decompress(g_q))   # 4x less DCI traffic
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedTree(NamedTuple):
    q: Any          # int8 pytree
    scale: Any      # f32 scalars per leaf


def compress(grads: Any) -> CompressedTree:
    def one(g):
        amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    qs = jax.tree_util.tree_map(lambda g: one(g)[0], grads)
    scales = jax.tree_util.tree_map(lambda g: one(g)[1], grads)
    return CompressedTree(qs, scales)


def decompress(c: CompressedTree) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)


def compress_with_feedback(grads: Any, residual: Any
                           ) -> Tuple[CompressedTree, Any]:
    """Quantize (grads + residual); the new residual is what quantization
    lost."""
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    c = compress(corrected)
    recon = decompress(c)
    new_residual = jax.tree_util.tree_map(
        lambda x, y: x - y, corrected, recon)
    return c, new_residual


def init_residual(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
