"""Fault-tolerance runtime: step timing, straggler detection, preemption
handling, elastic re-mesh planning.

At 1000+ nodes the failure model is: (a) hard node loss (process dies) —
covered by checkpoint/restart + elastic re-mesh; (b) slow nodes (thermal
throttling, failing HBM, network congestion) — detected here from per-step
timing statistics; (c) planned preemption (SIGTERM from the scheduler) —
handled by an immediate synchronous checkpoint.

All detection is host-side and cheap; the training loop calls
``monitor.record(step_time)`` once per step.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerReport:
    slow_hosts: List[int]
    median_s: float
    threshold_s: float
    recommendation: str


class StepMonitor:
    """Ring-buffer of per-host step times; flags hosts persistently slower
    than `threshold` x the fleet median."""

    def __init__(self, n_hosts: int, window: int = 32,
                 threshold: float = 1.5, min_samples: int = 8):
        self.n_hosts = n_hosts
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self._times: List[Deque[float]] = [deque(maxlen=window)
                                           for _ in range(n_hosts)]

    def record(self, host: int, step_time_s: float):
        self._times[host].append(step_time_s)

    def _medians(self) -> List[Optional[float]]:
        out = []
        for dq in self._times:
            if len(dq) < self.min_samples:
                out.append(None)
            else:
                s = sorted(dq)
                out.append(s[len(s) // 2])
        return out

    def check(self) -> Optional[StragglerReport]:
        meds = self._medians()
        valid = [m for m in meds if m is not None]
        if len(valid) < max(2, self.n_hosts // 2):
            return None
        fleet = sorted(valid)[len(valid) // 2]
        thr = fleet * self.threshold
        slow = [i for i, m in enumerate(meds) if m is not None and m > thr]
        if not slow:
            return None
        rec = (f're-mesh excluding hosts {slow} '
               f'(data axis {self.n_hosts} -> {self.n_hosts - len(slow)}); '
               'data pipeline is stateless-indexable so no reshuffle needed')
        return StragglerReport(slow, fleet, thr, rec)


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop checks each step; the
    loop then writes a synchronous checkpoint and exits cleanly."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev: Dict[int, object] = {}
        if install:
            for sig in (signal.SIGTERM,):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:   # not main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def elastic_serving_plan(n_healthy_devices: int, slots_per_device: int = 1
                         ) -> Tuple[Tuple[int, ...], Tuple[str, ...], int]:
    """Serving-side elastic plan: the slot-sharded engine's mesh is 1-D
    (every device is a slot shard on the ``data`` axis), so the largest
    mesh over the healthy devices is simply all of them.  Returns
    ``(mesh_shape, axis_names, slots)`` where ``slots`` keeps the
    per-device slot budget constant — dropping devices shrinks the slot
    buffer instead of overloading the survivors, rejoining devices grow
    it back.  The engine re-places in-flight latents into the resized
    buffer and parks any overflow, so a resize never kills a request."""
    if n_healthy_devices < 1:
        raise ValueError('not enough devices for one slot shard')
    if slots_per_device < 1:
        raise ValueError('slots_per_device must be >= 1')
    return ((n_healthy_devices,), ('data',),
            n_healthy_devices * slots_per_device)


def elastic_plan(n_healthy_hosts: int, model_parallel: int = 16
                 ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) mesh that fits the healthy hosts
    (8 chips/host).  Keeps the model axis intact (TP degree is a property of
    the model sharding); sheds data-parallel replicas first, then pods."""
    chips = n_healthy_hosts * 8
    model = model_parallel
    rows = chips // model
    if rows == 0:
        raise ValueError('not enough chips for one model replica')
    if rows >= 32:
        return ((rows // 16, 16, model), ('pod', 'data', 'model'))
    return ((rows, model), ('data', 'model'))
