"""Fault-tolerant checkpointing: sharded save/restore, async writer,
atomic commit, auto-resume, elastic resharding.

Layout (one directory per step):
    <dir>/step_000123/
        arrays.npz          flattened pytree leaves (logical, unsharded)
        meta.json           treedef + shapes + dtypes + step + mesh shape
        COMMITTED           empty marker written last (atomic commit)

Arrays are stored with *logical* shapes, so a checkpoint written on a
(2,16,16) mesh restores onto (16,16) or (1,8,8) unchanged — elasticity is a
restore-time resharding, not a file-format concern.  On a real multi-host
deployment each host would write its addressable shards (same layout, one
npz per host); the single-process fallback writes the whole tree.

The async writer moves `device_get` + file IO off the training thread; a
step barrier (`wait()`) guarantees at most one outstanding write so a crash
loses at most one checkpoint interval.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f'step_{step:08d}')

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r'step_(\d+)', name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 'COMMITTED')):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra_meta: Optional[dict] = None):
        """Snapshot `tree` at `step`.  With blocking=False the device->host
        copy happens synchronously (consistency) but file IO is async."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {'step': step, 'treedef': str(treedef),
                'n_leaves': len(host_leaves),
                'extra': extra_meta or {}}

        def _write():
            sd = self._step_dir(step)
            tmp = sd + '.tmp'
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, 'arrays.npz'),
                     **{f'a{i}': a for i, a in enumerate(host_leaves)})
            with open(os.path.join(tmp, 'meta.json'), 'w') as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, 'COMMITTED'), 'w'):
                pass
            if os.path.exists(sd):
                shutil.rmtree(sd)
            os.replace(tmp, sd)
            self._gc()

        if blocking:
            _write()
        else:
            def _guarded():
                try:
                    _write()
                except BaseException as e:   # surfaced at next wait()
                    self._error = e
            self._thread = threading.Thread(target=_guarded, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError('async checkpoint write failed') from err

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.dir)
            if (m := re.fullmatch(r'step_(\d+)', name))
            and os.path.exists(os.path.join(self.dir, name, 'COMMITTED')))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of `like`; if `shardings` (a pytree of
        NamedSharding) is given, leaves are placed sharded — this is the
        elastic-resharding path (any mesh, any host count)."""
        self.wait()
        sd = self._step_dir(step)
        data = np.load(os.path.join(sd, 'arrays.npz'))
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(data.files), \
            f'checkpoint has {len(data.files)} leaves, model has {len(leaves)}'
        arrays = [data[f'a{i}'] for i in range(len(leaves))]
        restored = treedef.unflatten(arrays)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored

    def restore_latest(self, like: Any, shardings: Optional[Any] = None
                       ) -> Tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like, shardings)
