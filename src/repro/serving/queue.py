"""Admission queue: priority classes with FIFO order inside each class.

Pure host-side bookkeeping — nothing here touches a device.  The queue
stamps each request's enqueue time so the engine can attribute queueing
delay separately from service time, and keeps an optional depth bound so
overload turns into rejected admissions instead of unbounded memory.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

from repro.serving.api import GenerationRequest


@dataclasses.dataclass(frozen=True)
class Queued:
    """A request plus its admission bookkeeping."""
    request: GenerationRequest
    enqueue_time: float


class AdmissionQueue:
    def __init__(self, max_depth: Optional[int] = None):
        self.max_depth = max_depth
        self._heap: List[Tuple[int, int, Queued]] = []
        self._seq = 0                 # FIFO tiebreak within a priority
        self.submitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, req: GenerationRequest, now: float = 0.0) -> bool:
        """Enqueue; returns False (rejected) when the queue is full."""
        if self.max_depth is not None and len(self._heap) >= self.max_depth:
            self.rejected += 1
            return False
        self._seq += 1
        heapq.heappush(self._heap,
                       (-req.priority, self._seq, Queued(req, now)))
        self.submitted += 1
        return True

    def pop(self) -> Optional[Queued]:
        """Highest-priority (then oldest) entry, or None when empty."""
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Queued]:
        """The entry ``pop`` would return, without removing it — the
        engine's phase-aligned admission looks ahead without committing
        (a held request keeps accruing queue delay until a refresh tick)."""
        if not self._heap:
            return None
        return self._heap[0][2]

    def oldest_wait(self, now: float) -> float:
        """Age of the oldest queued request (0 when empty)."""
        if not self._heap:
            return 0.0
        return max(0.0, now - min(q.enqueue_time
                                  for _, _, q in self._heap))
