"""Admission queue: priority classes with FIFO order inside each class,
a hard depth bound, and SLO-aware load shedding.

Pure host-side bookkeeping — nothing here touches a device.  The queue
stamps each request's enqueue time (and absolute deadline, when the
request carries an ``slo_ms``) so the engine can attribute queueing
delay separately from service time, and keeps an optional depth bound so
overload turns into *shed* load instead of unbounded memory.

Two shedding policies govern what happens when the bound is hit:

* ``'reject-newest'`` (default): the incoming request is turned away —
  classic tail drop, FIFO fairness, no reordering.
* ``'deadline-aware'``: the queued entry with the *earliest* absolute
  deadline (the one most likely to miss its SLO anyway) is evicted in
  favor of an incoming request with more slack; an arrival with less
  slack than everything queued is rejected instead.  Entries without an
  SLO have an infinite deadline and are never evicted.  Entries are
  stamped with their deadline under EVERY policy, and the engine calls
  ``expire()`` before admission whenever any queued entry carries one
  (``has_deadlines``) — so a request whose deadline already passed
  while queued is dropped rather than occupying a denoising slot it can
  only waste, regardless of the shed policy at the depth bound.

Shed accounting is split by cause: ``rejected`` (arrivals turned away at
the bound), ``evicted`` (queued entries displaced by deadline-aware
shedding) and ``expired`` (entries whose deadline passed while queued);
``shed`` is their sum.  ``on_shed`` (constructor arg or assignable
attribute) is the per-request observability hook: it fires as
``on_shed(reason, request, now)`` for every shed, with the SPECIFIC
request that was dropped — the engine wires it into its metrics and
tracer so a shed is attributable to a request id, not just a counter.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, List, Optional, Tuple, Union

from repro.serving.api import GenerationRequest

#: Valid ``shed_policy`` values.
SHED_POLICIES = ('reject-newest', 'deadline-aware')


@dataclasses.dataclass(frozen=True)
class Queued:
    """A request plus its admission bookkeeping.  ``deadline`` is the
    absolute serving-clock time by which the request must finish
    (``enqueue_time + slo_ms/1e3``; +inf when the request has no SLO)."""
    request: GenerationRequest
    enqueue_time: float
    deadline: float = math.inf


class AdmissionQueue:
    def __init__(self, max_depth: Optional[int] = None,
                 shed_policy: str = 'reject-newest',
                 on_shed: Optional[Callable[
                     [str, GenerationRequest, float], None]] = None):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f'unknown shed_policy {shed_policy!r} '
                             f'(expected one of {SHED_POLICIES})')
        self.max_depth = max_depth
        self.shed_policy = shed_policy
        self.on_shed = on_shed        # (reason, request, now) per shed
        self._heap: List[Tuple[int, int, Queued]] = []
        self._seq = 0                 # FIFO tiebreak within a priority
        self.submitted = 0
        self.rejected = 0             # arrivals turned away at the bound
        self.evicted = 0              # queued entries displaced (deadline)
        self.expired = 0              # deadline passed while queued

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def shed(self) -> int:
        """Total requests shed, across all causes."""
        return self.rejected + self.evicted + self.expired

    @property
    def has_deadlines(self) -> bool:
        """True when any queued entry carries a finite deadline.  The
        engine keys expiry on THIS, not on the shed policy: a request
        with an ``slo_ms`` must be expired even under ``reject-newest``
        or an unbounded queue — otherwise it can sit past its deadline
        and still take a denoising slot."""
        return any(e[2].deadline < math.inf for e in self._heap)

    @staticmethod
    def _deadline(req: GenerationRequest, now: float) -> float:
        return math.inf if req.slo_ms is None else now + req.slo_ms / 1e3

    def _notify_shed(self, reason: str, req: GenerationRequest,
                     now: float) -> None:
        if self.on_shed is not None:
            self.on_shed(reason, req, now)

    def submit(self, req: GenerationRequest, now: float = 0.0) -> bool:
        """Enqueue; returns False when the request was rejected.

        At the depth bound, ``'reject-newest'`` always returns False;
        ``'deadline-aware'`` evicts the queued entry with the earliest
        deadline when the arrival has strictly more slack (the arrival
        is admitted and ``evicted`` ticks up), and rejects the arrival
        otherwise."""
        deadline = self._deadline(req, now)
        if self.max_depth is not None and len(self._heap) >= self.max_depth:
            if self.shed_policy == 'deadline-aware' and self._heap:
                victim_i = min(range(len(self._heap)),
                               key=lambda i: (self._heap[i][2].deadline,
                                              -self._heap[i][1]))
                if self._heap[victim_i][2].deadline < deadline:
                    victim = self._heap.pop(victim_i)[2]
                    heapq.heapify(self._heap)
                    self.evicted += 1
                    self._notify_shed('evicted', victim.request, now)
                else:
                    self.rejected += 1
                    self._notify_shed('rejected', req, now)
                    return False
            else:
                self.rejected += 1
                self._notify_shed('rejected', req, now)
                return False
        self._seq += 1
        heapq.heappush(self._heap, (-req.priority, self._seq,
                                    Queued(req, now, deadline)))
        self.submitted += 1
        return True

    def pop(self) -> Optional[Queued]:
        """Highest-priority (then oldest) entry, or None when empty."""
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Queued]:
        """The entry ``pop`` would return, without removing it — the
        engine's phase-aligned admission looks ahead without committing
        (a held request keeps accruing queue delay until a refresh tick)."""
        if not self._heap:
            return None
        return self._heap[0][2]

    def expire(self, now: float,
               margin_s: Union[float,
                               Callable[[GenerationRequest], float]] = 0.0
               ) -> List[Queued]:
        """Remove and return every queued entry whose deadline has
        already passed (``deadline < now + margin_s``) — a dead request
        must never occupy a denoising slot.  ``margin_s`` lets the
        caller fold in an estimated service time so a request that
        *will* miss by the time it finishes is shed at admission too;
        pass a callable ``request -> seconds`` for per-request margins
        (the engine folds in ``steps x measured tick time``, which
        differs per request).  Counts into ``expired``."""
        margin = margin_s if callable(margin_s) else (lambda _r: margin_s)

        def dead_entry(e) -> bool:
            return e[2].deadline < now + margin(e[2].request)

        dead = [e for e in self._heap if dead_entry(e)]
        if not dead:
            return []
        self._heap = [e for e in self._heap if not dead_entry(e)]
        heapq.heapify(self._heap)
        self.expired += len(dead)
        out = [q for _, _, q in sorted(dead, key=lambda e: e[1])]
        for q in out:
            self._notify_shed('expired', q.request, now)
        return out

    def oldest_wait(self, now: float) -> float:
        """Age of the oldest queued request (0 when empty)."""
        if not self._heap:
            return 0.0
        return max(0.0, now - min(q.enqueue_time
                                  for _, _, q in self._heap))
