"""Serving metrics, per-request photonic energy and the accuracy-vs-EPB
frontier.

``PhotonicAccountant`` scales the UNet per-step operation counts
(``core/photonic/workload.py``) by the number of UNet evaluations a
request consumed (its DDIM steps, doubled under classifier-free
guidance) and runs them through ``simulator.simulate`` — so every
completed request reports the Joules DiffLight would have burned on it
and the corresponding energy-per-bit.  Accounting is precision-aware:
``w8a8`` / ``w8a8+noise`` requests ride the analog MR banks (the
simulated DiffLight numbers); ``fp32`` requests cannot — they are
attributed the paper's Fig. 10 GPU digital baseline (EPB anchored at
94.18x DiffLight, 32-bit operands), which is exactly the energy gap the
per-request precision knob trades against quality.

``ServingMetrics`` keeps the queue/latency ledger (p50/p95/p99 latency,
p50/p99 queue wait, requests/s, tick/occupancy counters, SLO
violations) plus the frontier: one ``FrontierPoint`` per completed
request (precision, EPB, energy, PSNR/MSE vs the fp32 reference when
probed) and per-policy aggregates surfaced in every snapshot.  All
counters are monotone in completed work.

Operability counters added by the cold-start / overload hardening:
``warmup_s`` (wall seconds the engine spent compiling at warmup),
``first_tick_s`` (engine construction to the completion of the first
*served* tick — the time-to-first-tick a restart pays), ``shed``
broken down by cause (``queue_full`` arrivals rejected at the depth
bound, ``deadline_evict`` queued entries displaced by deadline-aware
shedding, ``expired`` entries whose deadline passed while queued) and
``max_queue_depth`` (peak observed backlog — bounded queues stay at or
under their ``max_depth``).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.serving.api import GenerationResult

#: Fig. 10 anchor: DiffLight's average EPB improvement over the GPU
#: (RTX 4070) digital baseline — what an fp32 request is billed per bit.
FP32_DIGITAL_EPB_X = 94.18
#: fp32 operands carry 4x the bits of the 8-bit analog datapath.
FP32_BITS_X = 4.0


class PhotonicAccountant:
    """Per-request energy: workload counts x simulate(), per precision."""

    def __init__(self, unet_cfg, arch_cfg=None, ctx_len: Optional[int] = 77):
        from repro.core.photonic.arch import PAPER_OPTIMUM
        from repro.core.photonic.workload import unet_workload
        self.arch_cfg = arch_cfg or PAPER_OPTIMUM
        self.unet_cfg = unet_cfg
        self._per_step = unet_workload(
            unet_cfg, ctx_len=ctx_len if unet_cfg.context_dim else None)
        self._cache: Dict[float, 'object'] = {}
        self._shallow_frac: Optional[float] = None

    @property
    def shallow_fraction(self) -> float:
        """MAC fraction of a DeepCache skip pass vs a full UNet pass —
        the workload transform a skip tick is billed through."""
        if self._shallow_frac is None:
            from repro.diffusion.deepcache import shallow_workload_fraction
            self._shallow_frac = shallow_workload_fraction(self.unet_cfg)
        return self._shallow_frac

    def _report_factor(self, factor: float):
        from repro.core.photonic.simulator import simulate
        key = round(float(factor), 9)
        if key not in self._cache:
            self._cache[key] = simulate(
                self._per_step.scale(factor), self.arch_cfg,
                name=f'{self._per_step.name}/x{key:g}')
        return self._cache[key]

    def report(self, steps: int, guided: bool = False):
        """SimReport for one request: `steps` UNet evaluations (2x when
        classifier-free guidance runs the conditional + unconditional
        pass per step)."""
        return self._report_factor(steps * (2 if guided else 1))

    def report_evals(self, full_evals: int, cached_evals: int = 0,
                     guided: bool = False):
        """SimReport for a DeepCache-phased request: ``full_evals`` full
        UNet passes plus ``cached_evals`` shallow skip passes, each
        billed at ``shallow_fraction`` of a full pass (the DeepCache
        workload transform), doubled under classifier-free guidance."""
        mult = 2 if guided else 1
        factor = mult * (full_evals + cached_evals * self.shallow_fraction)
        return self._report_factor(factor)

    def energy(self, steps: int, guided: bool = False,
               precision: str = 'w8a8'):
        """(energy_j, epb_pj) for one request at the given precision.

        Quantized precisions return the DiffLight simulation unchanged
        (noise injection is free — the analog datapath is identical).
        ``fp32`` scales EPB by the GPU digital anchor and energy by
        anchor x 4 (32-bit vs 8-bit operands).
        """
        return self._price(self.report(steps, guided), precision)

    def energy_evals(self, full_evals: int, cached_evals: int = 0,
                     guided: bool = False, precision: str = 'w8a8'):
        """(energy_j, epb_pj) for a request that consumed ``full_evals``
        full ticks and ``cached_evals`` DeepCache skip ticks — skip ticks
        cost ``shallow_fraction`` of a full tick, so per-request energy
        drops on cached ticks at every precision."""
        return self._price(self.report_evals(full_evals, cached_evals,
                                             guided), precision)

    @staticmethod
    def _price(rep, precision: str):
        if precision == 'fp32':
            return (rep.energy_j * FP32_DIGITAL_EPB_X * FP32_BITS_X,
                    rep.epb_pj * FP32_DIGITAL_EPB_X)
        return rep.energy_j, rep.epb_pj


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One completed request on the accuracy-vs-energy frontier."""
    request_id: int
    precision: str
    epb_pj: float
    energy_j: float
    psnr_db: Optional[float]       # vs fp32 reference; None if not probed
    mse: Optional[float]


@dataclasses.dataclass
class MetricsSnapshot:
    submitted: int
    completed: int
    ticks: int
    unet_steps: int              # slot-steps of UNet work executed
    active_slots: int
    queued: int
    p50_latency_s: float
    p95_latency_s: float
    p99_latency_s: float
    requests_per_s: float
    total_energy_j: float
    slo_violations: int
    shed: int = 0                # total requests shed (all causes)
    shed_by_reason: Dict[str, int] = dataclasses.field(
        default_factory=dict)    # queue_full / deadline_evict / expired
    p50_queue_wait_s: float = 0.0
    p99_queue_wait_s: float = 0.0
    max_queue_depth: int = 0     # peak backlog observed at submit time
    # cold-start accounting (0.0 when never recorded)
    warmup_s: float = 0.0        # wall seconds spent in engine warmup
    first_tick_s: float = 0.0    # construction -> first served tick done
    # DeepCache / early-exit scheduler counters
    full_steps: int = 0          # slot-steps run as full UNet passes
    cached_steps: int = 0        # slot-steps run as shallow (skip) passes
    cache_hit_rate: float = 0.0  # cached_steps / unet_steps
    mixed_ticks: int = 0         # ticks paying BOTH a full and a skip pass
    early_exits: int = 0         # requests drained by x0 convergence
    steps_saved: int = 0         # total requested-minus-executed steps
    steps_saved_hist: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    # sharded-serving counters
    resizes: int = 0             # elastic mesh resizes survived
    devices: int = 1             # slot-shard count after the last resize
    overlapped_decodes: int = 0  # drains whose VAE decode overlapped the
    #                              next denoise tick (async dispatch)
    # accuracy-vs-EPB frontier: per-policy aggregates over completed work
    frontier: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)


class ServingMetrics:
    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.ticks = 0
        self.unet_steps = 0
        self.total_energy_j = 0.0
        self.slo_violations = 0
        self.shed = 0
        self.shed_by_reason: Dict[str, int] = {}
        self.max_queue_depth = 0
        self.warmup_s: Optional[float] = None
        self.first_tick_s: Optional[float] = None
        self._queue_waits: List[float] = []     # kept sorted
        self.full_steps = 0
        self.cached_steps = 0
        self.mixed_ticks = 0
        self.early_exits = 0
        self.steps_saved = 0
        self.steps_saved_hist: Dict[int, int] = {}
        self.resizes: List[Tuple[int, int]] = []    # (old, new) devices
        self.devices = 1
        self.overlapped_decodes = 0
        self.results: List[GenerationResult] = []
        self.frontier_points: List[FrontierPoint] = []
        self.latency_sum_s = 0.0      # summary _sum for the exposition
        self.queue_wait_sum_s = 0.0
        self._latencies: List[float] = []       # kept sorted
        self._first_submit: Optional[float] = None
        self._last_finish: Optional[float] = None
        self._by_policy: Dict[str, Dict[str, float]] = {}

    # -- recording ---------------------------------------------------------
    def record_submit(self, now: float):
        self.submitted += 1
        if self._first_submit is None or now < self._first_submit:
            self._first_submit = now

    def record_shed(self, reason: str = 'queue_full'):
        """One request shed.  ``reason``: ``'queue_full'`` (arrival
        rejected at the depth bound), ``'deadline_evict'`` (queued entry
        displaced by deadline-aware shedding) or ``'expired'`` (deadline
        passed while queued — dropped at admission)."""
        self.shed += 1
        self.shed_by_reason[reason] = self.shed_by_reason.get(reason, 0) + 1

    def observe_queue_depth(self, depth: int):
        """Track the peak backlog — a bounded queue's proof of bound."""
        if depth > self.max_queue_depth:
            self.max_queue_depth = depth

    def record_warmup(self, seconds: float):
        """Wall seconds spent compiling in ``engine.warmup`` (cumulative
        across warmup calls — one per served policy set)."""
        self.warmup_s = seconds if self.warmup_s is None \
            else self.warmup_s + seconds

    def record_first_tick(self, seconds: float):
        """Engine construction to completion of the first served tick —
        the cold-start time-to-first-tick.  First call wins."""
        if self.first_tick_s is None:
            self.first_tick_s = seconds

    def record_resize(self, old_devices: int, new_devices: int):
        """One elastic mesh resize survived (devices dropped/rejoined)."""
        self.resizes.append((old_devices, new_devices))
        self.devices = new_devices

    def record_overlapped_decode(self, n: int = 1):
        """Drains whose VAE decode was dispatched asynchronously and
        materialized only after the NEXT denoise tick launched."""
        self.overlapped_decodes += n

    def record_tick(self, active_slots: int,
                    full_slots: Optional[int] = None,
                    cached_slots: int = 0):
        """``full_slots`` / ``cached_slots`` split the tick's slot-steps
        into full-UNet and shallow DeepCache passes (default: all full).
        Under the phase-alignment invariant a tick is whole-batch full OR
        whole-batch shallow; ticks paying both (only possible when some
        requests opt out of caching) are tallied as ``mixed_ticks``."""
        self.ticks += 1
        self.unet_steps += active_slots
        if full_slots is None:
            full_slots = active_slots
        self.full_steps += full_slots
        self.cached_steps += cached_slots
        if full_slots > 0 and cached_slots > 0:
            self.mixed_ticks += 1

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of executed slot-steps served by the shallow pass."""
        return self.cached_steps / max(self.unet_steps, 1)

    def record_complete(self, res: GenerationResult,
                        slo_ms: Optional[float] = None):
        self.completed += 1
        self.results.append(res)
        bisect.insort(self._latencies, res.latency_s)
        bisect.insort(self._queue_waits, res.queue_delay_s)
        self.latency_sum_s += res.latency_s
        self.queue_wait_sum_s += res.queue_delay_s
        self.total_energy_j += res.energy_j
        self._last_finish = res.finish_time if self._last_finish is None \
            else max(self._last_finish, res.finish_time)
        if slo_ms is not None and res.latency_s * 1e3 > slo_ms:
            self.slo_violations += 1
        executed = res.steps if res.steps_executed is None \
            else res.steps_executed
        saved = res.steps - executed
        self.steps_saved += saved
        self.steps_saved_hist[saved] = self.steps_saved_hist.get(saved, 0) + 1
        if res.early_exit:
            self.early_exits += 1
        self.frontier_points.append(FrontierPoint(
            request_id=res.request_id, precision=res.precision,
            epb_pj=res.epb_pj, energy_j=res.energy_j,
            psnr_db=res.quality_psnr_db, mse=res.quality_mse))
        d = self._by_policy.setdefault(res.precision, {
            'completed': 0.0, 'energy_j': 0.0, 'epb_sum': 0.0,
            'probed': 0.0, 'psnr_sum': 0.0, 'mse_sum': 0.0,
            'steps_sum': 0.0, 'executed_sum': 0.0, 'saved_sum': 0.0,
            'full_evals': 0.0, 'cached_evals': 0.0, 'early_exits': 0.0})
        d['completed'] += 1
        d['energy_j'] += res.energy_j
        d['epb_sum'] += res.epb_pj
        d['steps_sum'] += res.steps
        d['executed_sum'] += executed
        d['saved_sum'] += saved
        d['full_evals'] += res.full_evals
        d['cached_evals'] += res.cached_evals
        d['early_exits'] += bool(res.early_exit)
        if res.quality_mse is not None:
            d['probed'] += 1
            d['mse_sum'] += res.quality_mse
            if res.quality_psnr_db is not None and \
                    math.isfinite(res.quality_psnr_db):
                d['psnr_sum'] += res.quality_psnr_db

    # -- reading -----------------------------------------------------------
    @staticmethod
    def _percentile(sorted_vals: List[float], p: float) -> float:
        """Nearest-rank percentile over a pre-sorted list (0.0 empty)."""
        if not sorted_vals:
            return 0.0
        idx = min(len(sorted_vals) - 1,
                  max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
        return sorted_vals[idx]

    def percentile_latency(self, p: float) -> float:
        """Nearest-rank latency percentile over completed requests."""
        return self._percentile(self._latencies, p)

    def percentile_queue_wait(self, p: float) -> float:
        """Nearest-rank queue-wait (submit -> slot start) percentile
        over completed requests."""
        return self._percentile(self._queue_waits, p)

    def requests_per_s(self) -> float:
        if (self.completed == 0 or self._first_submit is None
                or self._last_finish is None):
            return 0.0
        span = self._last_finish - self._first_submit
        return self.completed / max(span, 1e-9)

    def frontier(self) -> Dict[str, Dict[str, float]]:
        """Quality-vs-throughput/energy frontier: per-policy means over
        completed work.

        {precision: {completed, probed, mean_epb_pj, mean_energy_j,
                     mean_psnr_db, mean_mse, mean_steps_requested,
                     mean_steps_executed, mean_steps_saved,
                     cache_hit_rate, early_exits}} — PSNR/MSE means run
        over quality-probed requests only (NaN when none probed);
        ``cache_hit_rate`` is the fraction of this policy's executed
        ticks served by the shallow DeepCache pass, and
        ``mean_steps_saved`` the per-request step reduction from
        speculative early exit — together they say what the throughput
        win cost in steps, at the PSNR the probe reports.
        """
        out = {}
        for name, d in self._by_policy.items():
            n = max(d['completed'], 1.0)
            probed = d['probed']
            evals = max(d['full_evals'] + d['cached_evals'], 1.0)
            out[name] = {
                'completed': d['completed'],
                'probed': probed,
                'mean_epb_pj': d['epb_sum'] / n,
                'mean_energy_j': d['energy_j'] / n,
                'mean_psnr_db': (d['psnr_sum'] / probed) if probed
                else float('nan'),
                'mean_mse': (d['mse_sum'] / probed) if probed
                else float('nan'),
                'mean_steps_requested': d['steps_sum'] / n,
                'mean_steps_executed': d['executed_sum'] / n,
                'mean_steps_saved': d['saved_sum'] / n,
                'cache_hit_rate': d['cached_evals'] / evals,
                'early_exits': d['early_exits'],
            }
        return out

    def snapshot(self, active_slots: int = 0,
                 queued: int = 0) -> MetricsSnapshot:
        return MetricsSnapshot(
            submitted=self.submitted, completed=self.completed,
            ticks=self.ticks, unet_steps=self.unet_steps,
            active_slots=active_slots, queued=queued,
            p50_latency_s=self.percentile_latency(50),
            p95_latency_s=self.percentile_latency(95),
            p99_latency_s=self.percentile_latency(99),
            requests_per_s=self.requests_per_s(),
            total_energy_j=self.total_energy_j,
            slo_violations=self.slo_violations,
            shed=self.shed,
            shed_by_reason=dict(self.shed_by_reason),
            p50_queue_wait_s=self.percentile_queue_wait(50),
            p99_queue_wait_s=self.percentile_queue_wait(99),
            max_queue_depth=self.max_queue_depth,
            warmup_s=self.warmup_s or 0.0,
            first_tick_s=self.first_tick_s or 0.0,
            full_steps=self.full_steps,
            cached_steps=self.cached_steps,
            cache_hit_rate=self.cache_hit_rate,
            mixed_ticks=self.mixed_ticks,
            early_exits=self.early_exits,
            steps_saved=self.steps_saved,
            steps_saved_hist=dict(self.steps_saved_hist),
            resizes=len(self.resizes),
            devices=self.devices,
            overlapped_decodes=self.overlapped_decodes,
            frontier=self.frontier())

    def summary(self) -> Dict[str, float]:
        s = self.snapshot()
        out = {
            'completed': float(s.completed),
            'requests_per_s': s.requests_per_s,
            'p50_latency_ms': s.p50_latency_s * 1e3,
            'p95_latency_ms': s.p95_latency_s * 1e3,
            'p99_latency_ms': s.p99_latency_s * 1e3,
            'total_energy_mj': s.total_energy_j * 1e3,
            'energy_per_request_mj': (s.total_energy_j * 1e3 /
                                      max(s.completed, 1)),
            'slo_violations': float(s.slo_violations),
            'shed': float(s.shed),
            'deadline_sheds': float(
                s.shed_by_reason.get('deadline_evict', 0)
                + s.shed_by_reason.get('expired', 0)),
            'p50_queue_wait_ms': s.p50_queue_wait_s * 1e3,
            'p99_queue_wait_ms': s.p99_queue_wait_s * 1e3,
            'max_queue_depth': float(s.max_queue_depth),
            'warmup_s': s.warmup_s,
            'first_tick_s': s.first_tick_s,
            'cache_hit_rate': s.cache_hit_rate,
            'early_exits': float(s.early_exits),
            'steps_saved': float(s.steps_saved),
            'resizes': float(s.resizes),
            'devices': float(s.devices),
            'overlapped_decodes': float(s.overlapped_decodes),
        }
        # full shed breakdown, one key per cause — 'deadline_sheds'
        # stays as the two-cause aggregate for backward compatibility
        for reason, count in sorted(s.shed_by_reason.items()):
            out[f'shed_{reason}'] = float(count)
        return out
