"""Persistent compilation cache wiring — cold-start hardening.

A restarted serving process pays one XLA compilation per
``(precision, guided, refresh)`` step variant before it can serve its
first request: the recompile storm.  JAX's persistent compilation cache
keys each compiled executable by the hash of its lowered HLO and stores
it on disk, so a warm restart *loads* every step variant instead of
recompiling it — time-to-first-tick drops from compile-bound to
deserialize-bound.

``enable_persistent_cache`` routes every subsequent compilation in this
process through an on-disk directory.  It is process-global (the cache
is keyed by HLO hash, so unrelated programs sharing a directory are
fine) and idempotent.  The thresholds default to "cache everything":
the CPU-scale demo UNets compile in well under JAX's default 1-second
floor, which would silently skip them.

Usage (the engine and ``launch/serve.py --cache-dir`` call this for
you)::

    from repro.serving.compile_cache import enable_persistent_cache
    enable_persistent_cache('/var/cache/repro-xla')
    engine.warmup(precisions=('fp32', 'w8a8'))   # cold: compiles + stores
    # ... restart the process ...
    engine.warmup(precisions=('fp32', 'w8a8'))   # warm: loads from disk
"""
from __future__ import annotations

import os
from typing import Optional

import jax

#: The directory routed through ``enable_persistent_cache`` in this
#: process, or None when the persistent cache is off.
_ACTIVE_DIR: Optional[str] = None

#: Size bound (bytes) applied to the active directory, or None for
#: unbounded.  Enforced by ``trim_cache`` (LRU eviction), which the
#: engine calls after every warmup that populates the cache.
_MAX_BYTES: Optional[int] = None

#: Executables evicted by the size bound in this process — surfaced via
#: ``cache_entries(..., with_evictions=True)``.
_EVICTED = 0

#: Optional config flags applied best-effort (names vary across JAX
#: releases; absence is not an error).
_OPTIONAL_FLAGS = (
    # let XLA's own autotune/kernel caches piggyback on the directory
    ('jax_persistent_cache_enable_xla_caches', 'all'),
)


def enable_persistent_cache(cache_dir: str,
                            min_entry_size_bytes: int = -1,
                            min_compile_time_secs: float = 0.0,
                            max_bytes: Optional[int] = None) -> str:
    """Route every XLA compilation through a persistent on-disk cache.

    Creates ``cache_dir`` if needed and returns its absolute path.
    ``min_entry_size_bytes=-1`` / ``min_compile_time_secs=0.0`` cache
    every executable regardless of size or compile time (JAX's defaults
    skip sub-second compiles, which covers every CPU-scale demo model).
    Idempotent: re-enabling with the same directory is a no-op.

    ``max_bytes`` bounds the directory: a long-lived serving fleet
    accretes one executable per (model, mesh, step-variant) forever, so
    without a bound the cache dir grows without limit.  The bound is
    enforced now and after every engine warmup (``trim_cache``), evicting
    least-recently-used entries first.
    """
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(cache_dir, exist_ok=True)
    global _ACTIVE_DIR, _MAX_BYTES
    try:
        jax.config.update('jax_compilation_cache_dir', cache_dir)
        jax.config.update('jax_persistent_cache_min_entry_size_bytes',
                          min_entry_size_bytes)
        jax.config.update('jax_persistent_cache_min_compile_time_secs',
                          min_compile_time_secs)
    except AttributeError:                         # pragma: no cover
        # very old JAX: the experimental module is the only spelling
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)
        cc.set_cache_dir(cache_dir)
    for flag, value in _OPTIONAL_FLAGS:
        try:
            jax.config.update(flag, value)
        except (AttributeError, ValueError):       # pragma: no cover
            pass
    _reset_cache_state()
    if max_bytes is None and cache_dir == _ACTIVE_DIR:
        # idempotent re-enable (e.g. engine.warmup after an explicit
        # enable with a bound): keep the configured bound
        max_bytes = _MAX_BYTES
    _ACTIVE_DIR = cache_dir
    _MAX_BYTES = max_bytes
    if max_bytes is not None:
        trim_cache(cache_dir, max_bytes)
    return cache_dir


def _reset_cache_state() -> None:
    """Drop JAX's latched cache-used decision.  JAX checks "is a cache
    configured?" once, at the first compilation of the process — a serve
    process that compiled anything (even backend init probes) before
    ``enable_persistent_cache`` would otherwise silently never persist.
    The on-disk entries are untouched; only process state resets."""
    try:
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)
        cc.reset_cache()
    except Exception:                              # pragma: no cover
        pass


def disable_persistent_cache() -> None:
    """Turn the persistent cache off for subsequent compilations (tests
    use this to avoid leaking a temporary directory into later work)."""
    global _ACTIVE_DIR, _MAX_BYTES
    try:
        jax.config.update('jax_compilation_cache_dir', None)
    except AttributeError:                         # pragma: no cover
        pass
    _reset_cache_state()
    _ACTIVE_DIR = None
    _MAX_BYTES = None


def _entry_files(d: str):
    """(path, size, last_use) for every cache entry.  Last use is
    ``max(atime, mtime)``: reads bump atime where the filesystem tracks
    it, and mtime covers ``noatime`` mounts (creation order then stands
    in for recency — still the right eviction order for a write-once
    cache)."""
    out = []
    for name in os.listdir(d):
        path = os.path.join(d, name)
        if not os.path.isfile(path):
            continue
        st = os.stat(path)
        out.append((path, st.st_size, max(st.st_atime, st.st_mtime)))
    return out


def trim_cache(cache_dir: Optional[str] = None,
               max_bytes: Optional[int] = None) -> int:
    """Enforce the size bound on ``cache_dir`` (default: the active
    directory and its configured bound): evict least-recently-used
    executables until the directory fits.  Returns the number of
    entries evicted (also accumulated into the process-wide eviction
    counter).  A no-op when no bound is configured."""
    global _EVICTED
    d = cache_dir or _ACTIVE_DIR
    budget = max_bytes if max_bytes is not None else _MAX_BYTES
    if d is None or budget is None or not os.path.isdir(d):
        return 0
    files = _entry_files(d)
    total = sum(size for _, size, _ in files)
    if total <= budget:
        return 0
    evicted = 0
    for path, size, _ in sorted(files, key=lambda f: f[2]):
        if total <= budget:
            break
        try:
            os.remove(path)
        except OSError:                            # pragma: no cover
            continue                # concurrent reader won the race
        total -= size
        evicted += 1
    _EVICTED += evicted
    return evicted


def cache_evictions() -> int:
    """Executables evicted by the size bound in this process."""
    return _EVICTED


def active_cache_dir() -> Optional[str]:
    """The directory enabled in this process, or None."""
    return _ACTIVE_DIR


def cache_entries(cache_dir: Optional[str] = None,
                  with_evictions: bool = False):
    """Number of persisted executables in ``cache_dir`` (default: the
    active directory).  0 when the cache is off or the directory is
    empty — a cold/warm probe compares this before and after warmup.
    ``with_evictions=True`` returns ``(entries, evicted)`` so callers
    can tell an empty-because-cold directory from one the size bound
    has been evicting from."""
    d = cache_dir or _ACTIVE_DIR
    n = 0
    if d is not None and os.path.isdir(d):
        n = sum(1 for name in os.listdir(d)
                if os.path.isfile(os.path.join(d, name)))
    return (n, _EVICTED) if with_evictions else n
