"""Continuous-batching diffusion serving with photonic energy accounting
and per-request precision selection.

Quickstart::

    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), unet_cfg)
    engine = ContinuousBatchingEngine(pipe, slots=8)
    engine.warmup(precisions=('fp32', 'w8a8'))   # one compile per policy
    engine.submit(GenerationRequest(request_id=0, seed=42, steps=50,
                                    precision='w8a8'))
    while engine.busy:
        for result in engine.tick():
            ...  # result.image, result.energy_j, result.quality_psnr_db
    engine.metrics.snapshot().frontier   # accuracy-vs-EPB, per policy

``precision`` is per request (``'fp32' | 'w8a8' | 'w8a8+noise'``); the
engine groups compatible precisions per tick, so mixing them never
recompiles.  Quantized results carry PSNR/MSE against the fp32 reference
plus the DiffLight energy; fp32 results are billed the GPU digital
baseline — together they form the frontier in every metrics snapshot.
"""
from repro.core.precision import PrecisionPolicy
from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.batcher import (Bucket, BucketRouter, align_slots,
                                   bucket_for, choose_slots,
                                   group_by_precision, offered_load,
                                   overload_factor, plan_tick,
                                   split_cache_phase)
from repro.serving.compile_cache import (active_cache_dir, cache_entries,
                                         cache_evictions,
                                         disable_persistent_cache,
                                         enable_persistent_cache,
                                         trim_cache)
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.metrics import (FrontierPoint, PhotonicAccountant,
                                   ServingMetrics)
from repro.serving.queue import SHED_POLICIES, AdmissionQueue

__all__ = [
    'GenerationRequest', 'GenerationResult', 'ContinuousBatchingEngine',
    'AdmissionQueue', 'SHED_POLICIES', 'ServingMetrics',
    'PhotonicAccountant', 'PrecisionPolicy', 'FrontierPoint',
    'Bucket', 'BucketRouter', 'bucket_for', 'align_slots', 'choose_slots',
    'group_by_precision', 'offered_load', 'overload_factor',
    'plan_tick', 'split_cache_phase',
    'enable_persistent_cache', 'disable_persistent_cache',
    'active_cache_dir', 'cache_entries', 'cache_evictions', 'trim_cache',
]
