"""Continuous-batching diffusion serving with photonic energy accounting.

Quickstart::

    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), unet_cfg)
    engine = ContinuousBatchingEngine(pipe, slots=8)
    engine.warmup()
    engine.submit(GenerationRequest(request_id=0, seed=42, steps=50))
    while engine.busy:
        for result in engine.tick():
            ...  # result.image, result.latency_s, result.energy_j
"""
from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.batcher import (Bucket, BucketRouter, bucket_for,
                                   choose_slots)
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.metrics import PhotonicAccountant, ServingMetrics
from repro.serving.queue import AdmissionQueue

__all__ = [
    'GenerationRequest', 'GenerationResult', 'ContinuousBatchingEngine',
    'AdmissionQueue', 'ServingMetrics', 'PhotonicAccountant',
    'Bucket', 'BucketRouter', 'bucket_for', 'choose_slots',
]
