"""Request/response surface of the continuous-batching serving engine.

A ``GenerationRequest`` is one user's image: its own seed, its own DDIM
step count, its own guidance scale and an optional latency SLO.  The
engine multiplexes many of these into fixed-shape UNet step calls; a
``GenerationResult`` carries the decoded image plus the per-request
latency breakdown and the photonic energy the DiffLight simulator
attributes to exactly this request's denoising work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One image-generation request.

    ``arrival_time`` is the request's nominal arrival on the serving
    clock (seconds; used by trace replay).  ``priority``: larger values
    are admitted first; FIFO within a class.  ``slo_ms``: optional
    end-to-end latency objective — violations are tallied in the
    metrics, never enforced by dropping work.
    """
    request_id: int
    seed: int
    steps: int = 50
    guidance: float = 0.0
    priority: int = 0
    arrival_time: float = 0.0
    slo_ms: Optional[float] = None

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError(f'request {self.request_id}: steps must be >=1')


@dataclasses.dataclass
class GenerationResult:
    """Completed request: image plus timing and energy accounting."""
    request_id: int
    image: np.ndarray
    steps: int
    submit_time: float
    start_time: float
    finish_time: float
    energy_j: float = 0.0          # simulated DiffLight energy, this request
    epb_pj: float = 0.0            # energy-per-bit of the same workload

    @property
    def queue_delay_s(self) -> float:
        return self.start_time - self.submit_time

    @property
    def service_s(self) -> float:
        return self.finish_time - self.start_time

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.submit_time
