"""Request/response surface of the continuous-batching serving engine.

A ``GenerationRequest`` is one user's image: its own seed, its own DDIM
step count, its own guidance scale, an optional latency SLO — and its own
*precision*.  ``precision`` picks the accuracy-vs-energy point the
paper's analog photonic compute exposes: ``"fp32"`` (digital baseline),
``"w8a8"`` (the 8-bit MR-bank path, ~2 orders of magnitude lower EPB) or
``"w8a8+noise"`` (8-bit plus the analog perturbation model).  The engine
multiplexes many requests into fixed-shape UNet step calls, grouping
compatible precisions per tick; a ``GenerationResult`` carries the
decoded image plus the latency breakdown, the resolved
``PrecisionPolicy``, the photonic energy attributed to exactly this
request's denoising work, and — for sampled quantized requests — the
quality delta (PSNR/MSE) against the fp32 reference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.precision import PRECISION_NAMES, PrecisionPolicy


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One image-generation request.

    ``arrival_time`` is the request's nominal arrival on the serving
    clock (seconds; used by trace replay).  ``priority``: larger values
    are admitted first; FIFO within a class.  ``slo_ms``: optional
    end-to-end latency objective.  Violations of completed requests are
    always tallied in the metrics; additionally, when the engine's
    ``AdmissionQueue`` runs the ``'deadline-aware'`` shed policy, the
    SLO becomes an absolute deadline (``enqueue + slo_ms``): at the
    queue's depth bound the entry with the least slack is shed first,
    and a request whose deadline passes while queued is dropped at
    admission instead of occupying a slot.  ``precision``: one of
    ``'fp32' | 'w8a8' | 'w8a8+noise'`` — the execution policy for this
    request's UNet evaluations.

    Scheduler knobs (None = inherit the engine's defaults):

    ``cache_interval`` — DeepCache participation.  ``1`` opts this
    request out of feature caching (every tick is a full UNet pass);
    any value ``> 1`` opts in to the *engine's* shared refresh cadence
    (phase alignment means the engine interval governs the actual
    schedule, the per-request value only gates participation).

    ``exit_tol`` / ``exit_patience`` — speculative early exit: drain the
    request once the relative change of its x0 prediction,
    ``||x0_t - x0_{t-1}|| / ||x0_{t-1}||``, stays below ``exit_tol`` for
    ``exit_patience`` consecutive ticks.  ``exit_tol <= 0`` disables
    early exit for this request.

    ``trace_id`` — opaque caller-provided correlation id threaded
    through to the ``GenerationResult`` and every trace event the
    observability layer records for this request (None: the engine
    derives ``req-<request_id>``).  ``request_id`` stays the engine's
    primary key; ``trace_id`` exists so an upstream gateway can stitch
    serving spans into its own distributed trace.
    """
    request_id: int
    seed: int
    steps: int = 50
    guidance: float = 0.0
    priority: int = 0
    arrival_time: float = 0.0
    slo_ms: Optional[float] = None
    precision: str = 'fp32'
    cache_interval: Optional[int] = None
    exit_tol: Optional[float] = None
    exit_patience: Optional[int] = None
    trace_id: Optional[str] = None

    @property
    def effective_trace_id(self) -> str:
        """The caller's ``trace_id``, or the derived default."""
        return self.trace_id if self.trace_id is not None \
            else f'req-{self.request_id}'

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError(f'request {self.request_id}: steps must be >=1')
        if self.precision not in PRECISION_NAMES:
            raise ValueError(
                f'request {self.request_id}: unknown precision '
                f'{self.precision!r} (expected one of {PRECISION_NAMES})')
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f'request {self.request_id}: slo_ms must be '
                             '> 0 when given')
        if self.cache_interval is not None and self.cache_interval < 1:
            raise ValueError(f'request {self.request_id}: cache_interval '
                             'must be >= 1 when given')
        if self.exit_patience is not None and self.exit_patience < 1:
            raise ValueError(f'request {self.request_id}: exit_patience '
                             'must be >= 1 when given')


@dataclasses.dataclass
class GenerationResult:
    """Completed request: image plus timing, energy and quality accounting.

    ``policy`` is the resolved ``PrecisionPolicy`` the engine executed
    this request under.  ``quality_psnr_db`` / ``quality_mse`` compare
    the served output against the full-step fp32 reference for the same
    seed/steps/guidance — populated for quality-probed quantized,
    cached, or early-exited requests, ``None`` otherwise (full-step
    fp32 requests ARE the reference).

    Step accounting: ``steps`` is what the request *asked* for;
    ``steps_executed`` is how many denoise ticks actually ran (fewer
    when speculative early exit drained the slot), split into
    ``full_evals`` full-UNet passes and ``cached_evals`` shallow
    DeepCache passes.  ``early_exit`` marks a convergence drain.
    """
    request_id: int
    image: np.ndarray
    steps: int
    submit_time: float
    start_time: float
    finish_time: float
    energy_j: float = 0.0          # simulated DiffLight energy, this request
    epb_pj: float = 0.0            # energy-per-bit of the same workload
    precision: str = 'fp32'
    policy: Optional[PrecisionPolicy] = None
    quality_psnr_db: Optional[float] = None
    quality_mse: Optional[float] = None
    steps_executed: Optional[int] = None   # None = all requested steps ran
    full_evals: int = 0            # full-UNet denoise ticks consumed
    cached_evals: int = 0          # shallow (DeepCache skip) ticks consumed
    early_exit: bool = False       # drained by x0-convergence early exit
    trace_id: Optional[str] = None  # correlation id (request's, or derived)

    @property
    def steps_saved(self) -> int:
        """Requested-minus-executed steps (0 when the full trajectory ran)."""
        if self.steps_executed is None:
            return 0
        return self.steps - self.steps_executed

    @property
    def queue_delay_s(self) -> float:
        return self.start_time - self.submit_time

    @property
    def service_s(self) -> float:
        return self.finish_time - self.start_time

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.submit_time
