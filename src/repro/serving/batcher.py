"""Bucketing and slot-count policy.

A fixed-shape engine can only multiplex requests that agree on the
latent shape and model, so a fleet keys engines by ``Bucket`` —
(model name, resolution, channels).  ``choose_slots`` sizes an engine's
slot buffer from the offered load via Little's law: the steady-state
number of in-flight requests is arrival_rate x service_time; headroom
comes from the target utilization.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.engine import ContinuousBatchingEngine


@dataclasses.dataclass(frozen=True)
class Bucket:
    model: str
    img_size: int
    in_ch: int


def bucket_for(unet_cfg) -> Bucket:
    return Bucket(unet_cfg.name, unet_cfg.img_size, unet_cfg.in_ch)


def choose_slots(arrival_rate_hz: float, step_time_s: float,
                 mean_steps: float, target_util: float = 0.8,
                 max_slots: int = 64) -> int:
    """Little's law slot sizing: L = lambda x W, W ~ steps x step_time.

    Returns the slot count that keeps expected occupancy at
    ``target_util`` of the buffer, clamped to [1, max_slots].
    """
    if arrival_rate_hz <= 0 or step_time_s <= 0 or mean_steps <= 0:
        return 1
    in_flight = arrival_rate_hz * mean_steps * step_time_s
    return max(1, min(max_slots, math.ceil(in_flight / target_util)))


class BucketRouter:
    """Routes requests to per-bucket engines and drives them together."""

    def __init__(self):
        self._engines: Dict[Bucket, ContinuousBatchingEngine] = {}

    def register(self, engine: ContinuousBatchingEngine) -> Bucket:
        b = bucket_for(engine.pipe.unet_cfg)
        if b in self._engines:
            raise ValueError(f'bucket {b} already registered')
        self._engines[b] = engine
        return b

    def engine(self, bucket: Bucket) -> ContinuousBatchingEngine:
        return self._engines[bucket]

    @property
    def buckets(self) -> List[Bucket]:
        return list(self._engines)

    @property
    def busy(self) -> bool:
        return any(e.busy for e in self._engines.values())

    def submit(self, req: GenerationRequest, bucket: Optional[Bucket] = None,
               now: Optional[float] = None) -> bool:
        """Route to `bucket`, or to the single registered engine."""
        if bucket is None:
            if len(self._engines) != 1:
                raise ValueError('ambiguous routing: specify a bucket '
                                 f'({len(self._engines)} registered)')
            bucket = next(iter(self._engines))
        return self._engines[bucket].submit(req, now=now)

    def tick(self, now: Optional[float] = None) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        for e in self._engines.values():
            out.extend(e.tick(now))
        return out
