"""Bucketing, slot-count policy and per-tick precision grouping.

A fixed-shape engine can only multiplex requests that agree on the
latent shape and model, so a fleet keys engines by ``Bucket`` —
(model name, resolution, channels).  Precision is deliberately NOT part
of the bucket: one engine serves fp32 and w8a8 requests side by side by
grouping compatible-precision slots per tick (``group_by_precision``)
and running one pre-compiled step per group — mixed-precision arrivals
never force a recompile.  ``choose_slots`` sizes an engine's slot buffer
from the offered load via Little's law; it accepts either scalar load
terms or per-precision mappings (quantized steps are cheaper, so a
precision mix changes the in-flight occupancy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, TYPE_CHECKING

import numpy as np

from repro.serving.api import GenerationRequest, GenerationResult

if TYPE_CHECKING:                                      # pragma: no cover
    from repro.serving.engine import ContinuousBatchingEngine


@dataclasses.dataclass(frozen=True)
class Bucket:
    model: str
    img_size: int
    in_ch: int


def bucket_for(unet_cfg) -> Bucket:
    return Bucket(unet_cfg.name, unet_cfg.img_size, unet_cfg.in_ch)


def group_by_precision(
        precisions: Sequence[Optional[str]]) -> Dict[str, np.ndarray]:
    """Per-tick grouping of occupied slots by precision policy.

    ``precisions[i]`` is slot i's request precision (None = free slot).
    Returns {precision: bool mask over slots}.  The engine runs one
    pre-compiled step per group, masking the other groups' slots out —
    fixed shapes, so serving any precision mix needs zero recompiles
    after one warmup per policy.
    """
    groups: Dict[str, np.ndarray] = {}
    for i, name in enumerate(precisions):
        if name is None:
            continue
        mask = groups.setdefault(name, np.zeros(len(precisions), bool))
        mask[i] = True
    return groups


def split_cache_phase(mask: np.ndarray,
                      needs_refresh: np.ndarray
                      ) -> 'tuple[np.ndarray, np.ndarray]':
    """Split one precision group's slot mask into (refresh, skip) masks.

    ``needs_refresh[i]`` is True when slot i must run the full UNet pass
    this tick: the shared refresh cadence hit phase 0, the slot opted out
    of caching, or it has no cache yet (first step after admission).
    Phase-aligned admission (new requests snap onto the shared refresh
    cadence) makes every cache-enabled slot agree on this flag, so a tick
    is a whole-batch full pass or a whole-batch shallow pass — the skip
    masks returned here only mix with refresh masks when some requests
    opted out of caching (``ServingMetrics.mixed_ticks`` counts those).
    """
    mask = np.asarray(mask, bool)
    needs_refresh = np.asarray(needs_refresh, bool)
    return mask & needs_refresh, mask & ~needs_refresh


def plan_tick(precisions: Sequence[Optional[str]],
              needs_refresh: np.ndarray,
              caching: bool) -> 'List[tuple[str, bool, np.ndarray]]':
    """The ordered step-dispatch plan for one engine tick.

    Returns ``[(precision, refresh, mask), ...]`` — one entry per
    pre-compiled step call the tick must issue: occupied slots grouped
    by precision (``group_by_precision``), each group split into its
    refresh/skip submasks when DeepCache phasing is on
    (``split_cache_phase``); empty submasks are dropped.  Without
    caching every entry is a full pass (``refresh=True``).  Precisions
    dispatch in sorted order so the plan — and therefore the trace
    events tagged from it — is deterministic for a given slot state.
    """
    plan: 'List[tuple[str, bool, np.ndarray]]' = []
    groups = group_by_precision(precisions)
    for pname in sorted(groups):
        mask = groups[pname]
        if caching:
            r_m, s_m = split_cache_phase(mask, needs_refresh)
            pairs = ((True, r_m), (False, s_m))
        else:
            pairs = ((True, mask),)
        for refresh, m in pairs:
            if m.any():
                plan.append((pname, refresh, m))
    return plan


def align_slots(slots: int, n_shards: int) -> int:
    """Round a slot count up to a multiple of the mesh's slot-axis shard
    count, so the engine's ``(slots, H, W, C)`` latent buffer divides
    evenly over the ``data`` axis (every device carries the same number
    of slot rows)."""
    if slots < 1:
        raise ValueError('need at least one slot')
    if n_shards < 1:
        raise ValueError('need at least one slot shard')
    return ((slots + n_shards - 1) // n_shards) * n_shards


def _per_precision(value, key):
    return value[key] if isinstance(value, Mapping) else value


def offered_load(arrival_rate_hz, step_time_s, mean_steps) -> float:
    """Expected in-flight requests (Little's law L = lambda x W, with
    W ~ steps x step_time) for the offered traffic.  Each term may be a
    scalar or a per-precision mapping; per-precision loads add because
    the precisions share one slot buffer."""
    if isinstance(arrival_rate_hz, Mapping):
        return sum(
            rate * _per_precision(mean_steps, k) * _per_precision(
                step_time_s, k)
            for k, rate in arrival_rate_hz.items() if rate > 0)
    if arrival_rate_hz <= 0 or step_time_s <= 0 or mean_steps <= 0:
        return 0.0
    return arrival_rate_hz * mean_steps * step_time_s


def overload_factor(arrival_rate_hz, step_time_s, mean_steps,
                    slots: int) -> float:
    """Offered load over slot capacity: > 1 means arrivals exceed what
    ``slots`` concurrent requests can drain and a bounded queue WILL
    shed — the sizing anchor for overload traces (a "5x overload" trace
    has ``overload_factor == 5``)."""
    if slots < 1:
        raise ValueError('need at least one slot')
    return offered_load(arrival_rate_hz, step_time_s, mean_steps) / slots


def choose_slots(arrival_rate_hz, step_time_s, mean_steps,
                 target_util: float = 0.8, max_slots: int = 64,
                 n_shards: int = 1) -> int:
    """Little's law slot sizing: L = lambda x W, W ~ steps x step_time.

    Each load term may be a scalar or a per-precision mapping (e.g.
    ``arrival_rate_hz={'fp32': 1.0, 'w8a8': 4.0}`` with per-precision
    step times); precisions share one slot buffer, so their expected
    in-flight counts add.  Returns the slot count that keeps expected
    occupancy at ``target_util`` of the buffer, clamped to [1, max_slots].
    ``n_shards`` (the mesh's ``data``-axis size for a slot-sharded
    engine) rounds the result up so the buffer divides evenly.
    """
    in_flight = offered_load(arrival_rate_hz, step_time_s, mean_steps)
    if in_flight <= 0:
        return align_slots(1, n_shards)
    slots = max(1, min(max_slots, math.ceil(in_flight / target_util)))
    return align_slots(slots, n_shards)


class BucketRouter:
    """Routes requests to per-bucket engines and drives them together."""

    def __init__(self):
        self._engines: Dict[Bucket, 'ContinuousBatchingEngine'] = {}

    def register(self, engine: 'ContinuousBatchingEngine') -> Bucket:
        b = bucket_for(engine.pipe.unet_cfg)
        if b in self._engines:
            raise ValueError(f'bucket {b} already registered')
        self._engines[b] = engine
        return b

    def engine(self, bucket: Bucket) -> 'ContinuousBatchingEngine':
        return self._engines[bucket]

    @property
    def buckets(self) -> List[Bucket]:
        return list(self._engines)

    @property
    def busy(self) -> bool:
        return any(e.busy for e in self._engines.values())

    def submit(self, req: GenerationRequest, bucket: Optional[Bucket] = None,
               now: Optional[float] = None) -> bool:
        """Route to `bucket`, or to the single registered engine."""
        if bucket is None:
            if len(self._engines) != 1:
                raise ValueError('ambiguous routing: specify a bucket '
                                 f'({len(self._engines)} registered)')
            bucket = next(iter(self._engines))
        return self._engines[bucket].submit(req, now=now)

    def tick(self, now: Optional[float] = None) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        for e in self._engines.values():
            out.extend(e.tick(now))
        return out
