"""Continuous-batching diffusion engine: slot-based mixed-timestep steps.

The engine owns a fixed ``(slots, H, W, C)`` latent buffer.  Each slot
carries one in-flight request at its *own* DDIM step index — possible
because every denoise step is a single UNet call with a per-sample
timestep vector (``DiffusionPipeline.denoise_step``), so requests at
different denoising depths share one jitted step.  Per tick:

  1. free slots are refilled from the admission queue (each new request's
     initial noise is derived from its own seed, exactly as
     ``samplers.ddim_sample`` would);
  2. ONE fixed-shape mixed-timestep UNet step advances every active slot
     (inactive slots are masked out, their latents unchanged);
  3. slots that reached the end of their trajectory drain through the
     (fixed batch-1) VAE decode, report metrics + DiffLight energy, and
     are immediately refillable.

Every device function is jitted once against fixed shapes — after the
first tick touches each code path (step / place / take / decode) the
engine performs ZERO recompilations, which ``compile_stats()`` exposes
for tests to assert.

Output equivalence: with eta=0 DDIM is deterministic given the initial
noise, and the UNet treats batch elements independently, so a request
served through the engine is numerically identical to running
``DiffusionPipeline.generate(key=PRNGKey(seed), batch=1, steps=s)`` on
its own (tests pin this at atol 1e-5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion import samplers
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models import autoencoder as AE
from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.metrics import PhotonicAccountant, ServingMetrics
from repro.serving.queue import AdmissionQueue, Queued


@dataclasses.dataclass
class _Active:
    """One occupied slot: the request plus its trajectory cursor."""
    request: GenerationRequest
    ts: np.ndarray               # this request's DDIM timestep trajectory
    i: int                       # next step index into `ts`
    submit_time: float
    start_time: float


class ContinuousBatchingEngine:
    def __init__(self, pipe: DiffusionPipeline, slots: int = 4,
                 context=None, queue: Optional[AdmissionQueue] = None,
                 metrics: Optional[ServingMetrics] = None,
                 photonic: Optional[PhotonicAccountant] = None,
                 track_energy: bool = True):
        if slots < 1:
            raise ValueError('need at least one slot')
        self.pipe = pipe
        self.slots = slots
        self.context = context
        self.queue = queue or AdmissionQueue()
        self.metrics = metrics or ServingMetrics()
        self.photonic = photonic or (
            PhotonicAccountant(pipe.unet_cfg) if track_energy else None)
        cfg = pipe.unet_cfg
        self._sample_shape = (cfg.img_size, cfg.img_size, cfg.in_ch)
        self.x = jnp.zeros((slots,) + self._sample_shape, jnp.float32)
        self._slot: List[Optional[_Active]] = [None] * slots
        self._traj: Dict[int, np.ndarray] = {}
        self._wall_t0 = 0.0          # wall-clock origin (set by replay)

        sched = pipe.sched

        def make_step(use_guidance: bool):
            def step(x, t, t_prev, active, guidance):
                if use_guidance:
                    # per-slot classifier-free guidance: blend against the
                    # unconditional eps only for guided slots
                    eps_c = pipe._eps_fn(self.context, 0.0)(x, t)
                    eps_u = pipe._eps_fn(None, 0.0)(x, t)
                    g = guidance.reshape((-1,) + (1,) * (x.ndim - 1))
                    eps = jnp.where(g > 0, eps_u + g * (eps_c - eps_u),
                                    eps_c)
                    x_new = samplers.ddim_step(sched, eps, x, t, t_prev)
                else:
                    x_new = pipe.denoise_step(x, t, t_prev,
                                              context=self.context)
                mask = active.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.where(mask, x_new, x)
            return step

        # guided ticks pay the extra unconditional UNet pass only when
        # some active slot actually asked for guidance
        self._step = jax.jit(make_step(False), donate_argnums=(0,))
        self._step_guided = jax.jit(make_step(True), donate_argnums=(0,)) \
            if context is not None else None
        # initial noise exactly as ddim_sample: x = normal(split(key)[0], .)
        self._init_noise = jax.jit(lambda key: jax.random.normal(
            jax.random.split(key)[0], (1,) + self._sample_shape)[0])
        self._place = jax.jit(lambda x, i, v: x.at[i].set(v))
        self._take = jax.jit(lambda x, i: x[i])
        if pipe.vae_params is not None:
            self._decode = jax.jit(lambda z: AE.vae_decode(
                pipe.vae_params, pipe.vae_cfg, z))
        else:
            self._decode = None

    # -- introspection -----------------------------------------------------
    @property
    def active_count(self) -> int:
        return sum(a is not None for a in self._slot)

    @property
    def busy(self) -> bool:
        return self.active_count > 0 or len(self.queue) > 0

    def compile_stats(self) -> Dict[str, int]:
        """Per-jitted-function compile counts (cache sizes).  Constant
        after warmup == zero recompilation."""
        out = {}
        for name in ('_step', '_step_guided', '_init_noise', '_place',
                     '_take', '_decode'):
            fn = getattr(self, name)
            if fn is None:
                continue
            try:
                out[name] = int(fn._cache_size())
            except Exception:                      # pragma: no cover
                out[name] = -1
        return out

    # -- request flow ------------------------------------------------------
    def submit(self, req: GenerationRequest,
               now: Optional[float] = None) -> bool:
        now = time.perf_counter() if now is None else now
        ok = self.queue.submit(req, now)
        if ok:
            self.metrics.record_submit(now)
        return ok

    def _trajectory(self, steps: int) -> np.ndarray:
        if steps not in self._traj:
            self._traj[steps] = samplers.ddim_timesteps(
                self.pipe.sched, steps)
        return self._traj[steps]

    def _admit(self, now: float) -> None:
        for idx in range(self.slots):
            if self._slot[idx] is not None:
                continue
            q = self.queue.pop()
            if q is None:
                return
            req = q.request
            self._slot[idx] = _Active(
                request=req, ts=self._trajectory(req.steps), i=0,
                submit_time=q.enqueue_time, start_time=now)
            noise = self._init_noise(jax.random.PRNGKey(req.seed))
            self.x = self._place(self.x, jnp.int32(idx), noise)

    def _drain(self, idx: int, now: float,
               wall_clock: bool = False) -> GenerationResult:
        a = self._slot[idx]
        z = self._take(self.x, jnp.int32(idx))[None]
        if self._decode is not None:
            z = self._decode(z)
        req = a.request
        guided = req.guidance > 0.0 and self.context is not None
        energy_j = epb = 0.0
        if self.photonic is not None:
            energy_j, epb = self.photonic.energy(req.steps, guided)
        image = np.asarray(z[0])           # device sync: image materialized
        if wall_clock:
            # only now has the final step + decode actually executed
            now = time.perf_counter() - self._wall_t0
        res = GenerationResult(
            request_id=req.request_id, image=image,
            steps=req.steps, submit_time=a.submit_time,
            start_time=a.start_time, finish_time=now,
            energy_j=energy_j, epb_pj=epb)
        self.metrics.record_complete(res, slo_ms=req.slo_ms)
        self._slot[idx] = None
        return res

    def tick(self, now: Optional[float] = None,
             wall_clock: Optional[bool] = None) -> List[GenerationResult]:
        """Admit -> one mixed-timestep UNet step -> drain finished slots.

        ``wall_clock`` (default: `now` not given) makes drained results
        re-stamp their finish time after the device sync, so reported
        latencies include the final step + VAE decode."""
        wall_clock = (now is None) if wall_clock is None else wall_clock
        now = time.perf_counter() - self._wall_t0 if now is None else now
        self._admit(now)
        if self.active_count == 0:
            return []
        t = np.zeros(self.slots, np.int32)
        t_prev = np.full(self.slots, -1, np.int32)
        active = np.zeros(self.slots, bool)
        guidance = np.zeros(self.slots, np.float32)
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            active[idx] = True
            t[idx] = a.ts[a.i]
            t_prev[idx] = a.ts[a.i + 1] if a.i + 1 < len(a.ts) else -1
            guidance[idx] = a.request.guidance
        self.metrics.record_tick(int(active.sum()))
        step_fn = self._step_guided if (self._step_guided is not None
                                        and guidance.any()) else self._step
        self.x = step_fn(self.x, jnp.asarray(t), jnp.asarray(t_prev),
                         jnp.asarray(active), jnp.asarray(guidance))
        done: List[GenerationResult] = []
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            a.i += 1
            if a.i >= len(a.ts):
                done.append(self._drain(idx, now, wall_clock=wall_clock))
        return done

    def run_until_idle(self, now: Optional[float] = None,
                       max_ticks: int = 100_000,
                       tick_dt: float = 0.0) -> List[GenerationResult]:
        """Drive ticks until queue and slots are empty.  With a logical
        clock (`now` given), each tick advances it by `tick_dt`."""
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            if not self.busy:
                return results
            results.extend(self.tick(now))
            if now is not None:
                now += tick_dt
        raise RuntimeError(f'engine still busy after {max_ticks} ticks')

    def replay(self, requests: List[GenerationRequest],
               max_ticks: int = 1_000_000) -> List[GenerationResult]:
        """Wall-clock replay of an arrival trace: each request is
        submitted once the serving clock passes its ``arrival_time``;
        the engine idles (sleeps) when nothing has arrived yet."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        t0 = self._wall_t0 = time.perf_counter()
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            now = time.perf_counter() - t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0), now=now)
            if not self.busy:
                if not pending:
                    return results
                time.sleep(max(0.0, pending[0].arrival_time - now))
                continue
            # async dispatch overlaps host bookkeeping with device compute;
            # every drain materializes its image (device sync), so dispatch
            # can run ahead by at most one request's remaining steps
            results.extend(self.tick(now=time.perf_counter() - t0,
                                     wall_clock=True))
        raise RuntimeError('replay exceeded max_ticks')

    def warmup(self) -> None:
        """Compile every code path (step, place, take, decode) with a
        throwaway request so serving ticks never pay compile time."""
        saved_q, saved_m = self.queue, self.metrics
        self.queue, self.metrics = AdmissionQueue(), ServingMetrics()
        try:
            self.submit(GenerationRequest(request_id=-1, seed=0, steps=1),
                        now=0.0)
            self.run_until_idle(now=0.0)
            if self._step_guided is not None:
                # separately: the guided tick variant
                self.submit(GenerationRequest(request_id=-2, seed=0,
                                              steps=1, guidance=7.5),
                            now=0.0)
                self.run_until_idle(now=0.0)
        finally:
            self.queue, self.metrics = saved_q, saved_m
