"""Continuous-batching diffusion engine: slot-based mixed-timestep steps
with per-request precision policies.

The engine owns a fixed ``(slots, H, W, C)`` latent buffer.  Each slot
carries one in-flight request at its *own* DDIM step index — possible
because every denoise step is a single UNet call with a per-sample
timestep vector (``DiffusionPipeline.denoise_step``), so requests at
different denoising depths share one jitted step.  Each request also
carries its own *precision* (``fp32`` / ``w8a8`` / ``w8a8+noise``); the
engine resolves it to a frozen ``PrecisionPolicy`` and keeps one jitted
step per (policy, guided) pair.  Per tick:

  1. free slots are refilled from the admission queue (each new request's
     initial noise is derived from its own seed, exactly as
     ``samplers.ddim_sample`` would);
  2. active slots are grouped by precision (``batcher.group_by_precision``)
     and ONE fixed-shape mixed-timestep UNet step per group advances that
     group's slots (other slots are masked out, their latents unchanged) —
     so a mixed-precision tick costs one pre-compiled call per distinct
     policy, never a recompile;
  3. slots that reached the end of their trajectory drain through the
     (fixed batch-1) VAE decode, report metrics + policy-aware energy
     (w8a8 rides the DiffLight simulation; fp32 is billed the GPU digital
     baseline), and are immediately refillable.  Sampled quantized
     requests additionally run an eager fp32 reference for the same
     seed/steps/guidance and report PSNR/MSE against it — the per-request
     points of the accuracy-vs-EPB frontier.

Every device function is jitted once against fixed shapes — after one
warmup per policy (``warmup(precisions=...)``) the engine performs ZERO
recompilations, which ``compile_stats()`` exposes for tests to assert.

Output equivalence: with eta=0 DDIM is deterministic given the initial
noise, and both the UNet and the per-row w8a8 activation scales treat
batch elements independently, so a request served through the engine —
at fp32 OR w8a8 — is numerically identical to running
``DiffusionPipeline.generate(key=PRNGKey(seed), batch=1, steps=s,
policy=...)`` on its own (tests pin this at atol 1e-5).  ``w8a8+noise``
is deterministic under the engine's noise seed: two engines with the same
seed and request sequence produce identical images.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionPolicy
from repro.diffusion import samplers
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models import autoencoder as AE
from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.batcher import group_by_precision
from repro.serving.metrics import PhotonicAccountant, ServingMetrics
from repro.serving.queue import AdmissionQueue, Queued


@dataclasses.dataclass
class _Active:
    """One occupied slot: the request plus its trajectory cursor."""
    request: GenerationRequest
    ts: np.ndarray               # this request's DDIM timestep trajectory
    i: int                       # next step index into `ts`
    submit_time: float
    start_time: float


class ContinuousBatchingEngine:
    def __init__(self, pipe: DiffusionPipeline, slots: int = 4,
                 context=None, queue: Optional[AdmissionQueue] = None,
                 metrics: Optional[ServingMetrics] = None,
                 photonic: Optional[PhotonicAccountant] = None,
                 track_energy: bool = True,
                 noise_model=None, noise_seed: int = 0,
                 quality_probe: int = 1):
        """``noise_model`` / ``noise_seed`` configure the ``w8a8+noise``
        policy (defaults: the paper's analog perturbation model, seed 0).
        ``quality_probe``: run the fp32 reference + PSNR/MSE probe for
        every k-th completed quantized request (0 disables probing)."""
        if slots < 1:
            raise ValueError('need at least one slot')
        self.pipe = pipe
        self.slots = slots
        self.context = context
        self.queue = queue or AdmissionQueue()
        self.metrics = metrics or ServingMetrics()
        self.photonic = photonic or (
            PhotonicAccountant(pipe.unet_cfg) if track_energy else None)
        self.noise_model = noise_model
        self.noise_seed = noise_seed
        self.quality_probe = quality_probe
        cfg = pipe.unet_cfg
        self._sample_shape = (cfg.img_size, cfg.img_size, cfg.in_ch)
        self.x = jnp.zeros((slots,) + self._sample_shape, jnp.float32)
        self._slot: List[Optional[_Active]] = [None] * slots
        self._traj: Dict[int, np.ndarray] = {}
        self._wall_t0 = 0.0          # wall-clock origin (set by replay)
        self._quant_done = 0         # completed quantized requests (probe)
        # precision machinery: policies and jitted steps are built lazily,
        # one step per (precision, guided) pair, each closing over its
        # frozen PrecisionPolicy — new policies never disturb compiled ones
        self._policies: Dict[str, PrecisionPolicy] = {}
        self._steps: Dict[Tuple[str, bool], 'jax.stages.Wrapped'] = {}
        self._zero_key = jax.random.PRNGKey(0)     # inert key, fp32/w8a8

        # initial noise exactly as ddim_sample: x = normal(split(key)[0], .)
        self._init_noise = jax.jit(lambda key: jax.random.normal(
            jax.random.split(key)[0], (1,) + self._sample_shape)[0])
        self._place = jax.jit(lambda x, i, v: x.at[i].set(v))
        self._take = jax.jit(lambda x, i: x[i])
        if pipe.vae_params is not None:
            self._decode = jax.jit(lambda z: AE.vae_decode(
                pipe.vae_params, pipe.vae_cfg, z))
        else:
            self._decode = None

    # -- precision machinery ------------------------------------------------
    def _policy_for(self, name: str) -> PrecisionPolicy:
        """Resolve a request's precision name to this engine's policy."""
        if name not in self._policies:
            if name == 'fp32':
                pol = PrecisionPolicy.fp32()
            elif name == 'w8a8':
                cal = self.pipe.policy.calibration \
                    if self.pipe.policy.quantized else 'dynamic'
                pol = PrecisionPolicy.w8a8(calibration=cal)
            else:  # 'w8a8+noise' (request validation guarantees the name)
                pol = PrecisionPolicy.w8a8_noise(
                    model=self.noise_model, noise_seed=self.noise_seed)
            self._policies[name] = pol
        return self._policies[name]

    def _make_step(self, pol: PrecisionPolicy, use_guidance: bool):
        pipe, sched = self.pipe, self.pipe.sched

        def step(x, t, t_prev, active, guidance, key):
            nkey = key if pol.noisy else None
            if use_guidance:
                # per-slot classifier-free guidance: blend against the
                # unconditional eps only for guided slots.  Under a noisy
                # policy the unconditional pass draws independent noise.
                ukey = jax.random.fold_in(key, 1) if pol.noisy else None
                eps_c = pipe._eps_fn(self.context, 0.0, policy=pol,
                                     noise_key=nkey)(x, t)
                eps_u = pipe._eps_fn(None, 0.0, policy=pol,
                                     noise_key=ukey)(x, t)
                g = guidance.reshape((-1,) + (1,) * (x.ndim - 1))
                eps = jnp.where(g > 0, eps_u + g * (eps_c - eps_u), eps_c)
                x_new = samplers.ddim_step(sched, eps, x, t, t_prev)
            else:
                x_new = pipe.denoise_step(x, t, t_prev, context=self.context,
                                          policy=pol, noise_key=nkey)
            mask = active.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.where(mask, x_new, x)
        return step

    def _get_step(self, precision: str, guided: bool):
        k = (precision, guided)
        if k not in self._steps:
            pol = self._policy_for(precision)
            self._steps[k] = jax.jit(self._make_step(pol, guided),
                                     donate_argnums=(0,))
        return self._steps[k]

    def _tick_key(self, pol: PrecisionPolicy, tick_idx: int):
        """Per-tick analog-noise key: the policy's seed anchor folded with
        the tick index, so draws vary along every trajectory yet the whole
        serving run is deterministic under (seed, request sequence)."""
        if not pol.noisy:
            return self._zero_key
        return jax.random.fold_in(
            jax.random.PRNGKey(pol.noise_seed), tick_idx)

    # -- introspection -----------------------------------------------------
    @property
    def active_count(self) -> int:
        return sum(a is not None for a in self._slot)

    @property
    def busy(self) -> bool:
        return self.active_count > 0 or len(self.queue) > 0

    def compile_stats(self) -> Dict[str, int]:
        """Per-jitted-function compile counts (cache sizes).  Constant
        after one warmup per served policy == zero recompilation.  Step
        entries are labeled ``_step`` / ``_step_guided`` for fp32 and
        ``_step[w8a8]``-style for quantized policies."""
        out = {}
        for (pname, guided), fn in self._steps.items():
            label = ('_step_guided' if guided else '_step') + (
                '' if pname == 'fp32' else f'[{pname}]')
            try:
                out[label] = int(fn._cache_size())
            except Exception:                      # pragma: no cover
                out[label] = -1
        for name in ('_init_noise', '_place', '_take', '_decode'):
            fn = getattr(self, name)
            if fn is None:
                continue
            try:
                out[name] = int(fn._cache_size())
            except Exception:                      # pragma: no cover
                out[name] = -1
        return out

    # -- request flow ------------------------------------------------------
    def submit(self, req: GenerationRequest,
               now: Optional[float] = None) -> bool:
        now = time.perf_counter() if now is None else now
        ok = self.queue.submit(req, now)
        if ok:
            self.metrics.record_submit(now)
        return ok

    def _trajectory(self, steps: int) -> np.ndarray:
        if steps not in self._traj:
            self._traj[steps] = samplers.ddim_timesteps(
                self.pipe.sched, steps)
        return self._traj[steps]

    def _admit(self, now: float) -> None:
        for idx in range(self.slots):
            if self._slot[idx] is not None:
                continue
            q = self.queue.pop()
            if q is None:
                return
            req = q.request
            self._slot[idx] = _Active(
                request=req, ts=self._trajectory(req.steps), i=0,
                submit_time=q.enqueue_time, start_time=now)
            noise = self._init_noise(jax.random.PRNGKey(req.seed))
            self.x = self._place(self.x, jnp.int32(idx), noise)

    def _fp32_reference(self, req: GenerationRequest,
                        guided: bool) -> np.ndarray:
        """Eager fp32 generation for the same seed/steps/guidance — the
        quality probe's reference image (context row 0 stands in for the
        engine's shared conditioning)."""
        ctx = self.context[:1] if (guided and self.context is not None) \
            else None
        ref = self.pipe.generate(
            jax.random.PRNGKey(req.seed), batch=1, steps=req.steps,
            context=ctx, guidance=req.guidance if guided else 0.0,
            policy=PrecisionPolicy.fp32())
        return np.asarray(ref[0])

    @staticmethod
    def _quality(image: np.ndarray, ref: np.ndarray):
        """(mse, psnr_db) of the served image vs the fp32 reference."""
        mse = float(np.mean((image.astype(np.float64) -
                             ref.astype(np.float64)) ** 2))
        rng = float(ref.max() - ref.min()) or 1.0
        psnr = math.inf if mse <= 0.0 else 10.0 * math.log10(rng * rng / mse)
        return mse, psnr

    def _drain(self, idx: int, now: float,
               wall_clock: bool = False) -> GenerationResult:
        a = self._slot[idx]
        z = self._take(self.x, jnp.int32(idx))[None]
        if self._decode is not None:
            z = self._decode(z)
        req = a.request
        pol = self._policy_for(req.precision)
        guided = req.guidance > 0.0 and self.context is not None
        energy_j = epb = 0.0
        if self.photonic is not None:
            energy_j, epb = self.photonic.energy(req.steps, guided,
                                                 precision=req.precision)
        image = np.asarray(z[0])           # device sync: image materialized
        if wall_clock:
            # only now has the final step + decode actually executed
            now = time.perf_counter() - self._wall_t0
        # quality probe AFTER the latency stamp: the eager fp32 reference
        # is measurement apparatus, not served work
        mse = psnr = None
        if pol.quantized and self.quality_probe > 0:
            if self._quant_done % self.quality_probe == 0:
                mse, psnr = self._quality(
                    image, self._fp32_reference(req, guided))
            self._quant_done += 1
        res = GenerationResult(
            request_id=req.request_id, image=image,
            steps=req.steps, submit_time=a.submit_time,
            start_time=a.start_time, finish_time=now,
            energy_j=energy_j, epb_pj=epb,
            precision=req.precision, policy=pol,
            quality_psnr_db=psnr, quality_mse=mse)
        self.metrics.record_complete(res, slo_ms=req.slo_ms)
        self._slot[idx] = None
        return res

    def tick(self, now: Optional[float] = None,
             wall_clock: Optional[bool] = None) -> List[GenerationResult]:
        """Admit -> one mixed-timestep UNet step per precision group ->
        drain finished slots.

        ``wall_clock`` (default: `now` not given) makes drained results
        re-stamp their finish time after the device sync, so reported
        latencies include the final step + VAE decode."""
        wall_clock = (now is None) if wall_clock is None else wall_clock
        now = time.perf_counter() - self._wall_t0 if now is None else now
        self._admit(now)
        if self.active_count == 0:
            return []
        t = np.zeros(self.slots, np.int32)
        t_prev = np.full(self.slots, -1, np.int32)
        guidance = np.zeros(self.slots, np.float32)
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            t[idx] = a.ts[a.i]
            t_prev[idx] = a.ts[a.i + 1] if a.i + 1 < len(a.ts) else -1
            guidance[idx] = a.request.guidance
        groups = group_by_precision(
            [a.request.precision if a is not None else None
             for a in self._slot])
        tick_idx = self.metrics.ticks
        self.metrics.record_tick(
            int(sum(m.sum() for m in groups.values())))
        # one pre-compiled masked step per precision group; donated latent
        # buffers chain group to group, so slots outside the running group
        # pass through each call untouched
        for pname in sorted(groups):
            mask = groups[pname]
            g = np.where(mask, guidance, 0.0).astype(np.float32)
            guided = self.context is not None and bool(g.any())
            step_fn = self._get_step(pname, guided)
            key = self._tick_key(self._policy_for(pname), tick_idx)
            self.x = step_fn(self.x, jnp.asarray(t), jnp.asarray(t_prev),
                             jnp.asarray(mask), jnp.asarray(g), key)
        done: List[GenerationResult] = []
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            a.i += 1
            if a.i >= len(a.ts):
                done.append(self._drain(idx, now, wall_clock=wall_clock))
        return done

    def run_until_idle(self, now: Optional[float] = None,
                       max_ticks: int = 100_000,
                       tick_dt: float = 0.0) -> List[GenerationResult]:
        """Drive ticks until queue and slots are empty.  With a logical
        clock (`now` given), each tick advances it by `tick_dt`."""
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            if not self.busy:
                return results
            results.extend(self.tick(now))
            if now is not None:
                now += tick_dt
        raise RuntimeError(f'engine still busy after {max_ticks} ticks')

    def replay(self, requests: List[GenerationRequest],
               max_ticks: int = 1_000_000) -> List[GenerationResult]:
        """Wall-clock replay of an arrival trace: each request is
        submitted once the serving clock passes its ``arrival_time``;
        the engine idles (sleeps) when nothing has arrived yet."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        t0 = self._wall_t0 = time.perf_counter()
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            now = time.perf_counter() - t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0), now=now)
            if not self.busy:
                if not pending:
                    return results
                time.sleep(max(0.0, pending[0].arrival_time - now))
                continue
            # async dispatch overlaps host bookkeeping with device compute;
            # every drain materializes its image (device sync), so dispatch
            # can run ahead by at most one request's remaining steps
            results.extend(self.tick(now=time.perf_counter() - t0,
                                     wall_clock=True))
        raise RuntimeError('replay exceeded max_ticks')

    def warmup(self, precisions=('fp32',)) -> None:
        """Compile every code path (per-policy steps, place, take, decode)
        with throwaway requests so serving ticks never pay compile time.
        Pass every precision the engine will serve — e.g.
        ``warmup(('fp32', 'w8a8', 'w8a8+noise'))`` — one step compile per
        (policy, guided) pair, zero recompiles after."""
        saved_q, saved_m = self.queue, self.metrics
        saved_probe = self.quality_probe
        self.queue, self.metrics = AdmissionQueue(), ServingMetrics()
        self.quality_probe = 0          # no fp32 references for throwaways
        try:
            for i, pname in enumerate(precisions):
                self.submit(GenerationRequest(request_id=-(2 * i + 1),
                                              seed=0, steps=1,
                                              precision=pname), now=0.0)
                self.run_until_idle(now=0.0)
                if self.context is not None:
                    # separately: the guided tick variant
                    self.submit(GenerationRequest(request_id=-(2 * i + 2),
                                                  seed=0, steps=1,
                                                  guidance=7.5,
                                                  precision=pname), now=0.0)
                    self.run_until_idle(now=0.0)
        finally:
            self.queue, self.metrics = saved_q, saved_m
            self.quality_probe = saved_probe
