"""Continuous-batching diffusion engine: slot-based mixed-timestep steps
with per-request precision policies.

The engine owns a fixed ``(slots, H, W, C)`` latent buffer.  Each slot
carries one in-flight request at its *own* DDIM step index — possible
because every denoise step is a single UNet call with a per-sample
timestep vector (``DiffusionPipeline.denoise_step``), so requests at
different denoising depths share one jitted step.  Each request also
carries its own *precision* (``fp32`` / ``w8a8`` / ``w8a8+noise``); the
engine resolves it to a frozen ``PrecisionPolicy`` and keeps one jitted
step per (policy, guided) pair.  Per tick:

  1. free slots are refilled from the admission queue (each new request's
     initial noise is derived from its own seed, exactly as
     ``samplers.ddim_sample`` would);
  2. active slots are grouped by precision (``batcher.group_by_precision``)
     and ONE fixed-shape mixed-timestep UNet step per group advances that
     group's slots (other slots are masked out, their latents unchanged) —
     so a mixed-precision tick costs one pre-compiled call per distinct
     policy, never a recompile;
  3. slots that reached the end of their trajectory drain through the
     (fixed batch-1) VAE decode, report metrics + policy-aware energy
     (w8a8 rides the DiffLight simulation; fp32 is billed the GPU digital
     baseline), and are immediately refillable.  Sampled quantized
     requests additionally run an eager fp32 reference for the same
     seed/steps/guidance and report PSNR/MSE against it — the per-request
     points of the accuracy-vs-EPB frontier.

Two cooperating schedulers make the per-tick step cost *dynamic*:

  * **DeepCache-phased slots** (``cache_interval > 1``): the engine owns
    a batched slot-axis feature-cache buffer ``(slots, ...)`` and keeps
    exactly TWO pre-compiled step variants per (policy, guided) pair —
    a *refresh* step (full UNet pass, rewrites the cache rows) and a
    *skip* step (shallow pass splicing in the cached deep features).
    All cache-enabled slots share one refresh cadence: admission snaps
    new requests onto phase 0 of the cadence (a queued request is held
    until the next refresh tick), so every skip tick is a whole-batch
    shallow pass.  The photonic accountant bills skip ticks through the
    DeepCache workload transform (``shallow_workload_fraction``) instead
    of a full-UNet tick.
  * **Speculative early-exit draining** (``exit_tol``): every step also
    surfaces the x0 prediction from ``samplers.ddim_step``; the engine
    tracks the per-slot relative change ``||x0_t - x0_{t-1}||`` and
    drains a slot whose prediction stayed within ``exit_tol`` for
    ``exit_patience`` consecutive ticks, committing the converged x0 as
    the result — per-request step counts become dynamic and the freed
    slot is immediately available to queued work.

Every device function is jitted once against fixed shapes — after one
warmup per policy (``warmup(precisions=...)``) the engine performs ZERO
recompilations, which ``compile_stats()`` exposes for tests to assert;
enabling caching adds exactly the refresh/skip pair per (policy,
guided), never more.

Cold-start and overload hardening:

  * ``warmup(..., cache_dir=...)`` routes every compilation through
    JAX's persistent on-disk cache (``serving/compile_cache.py``), so a
    restarted engine *loads* its step variants instead of recompiling —
    the recompile storm becomes a cache read.  ``aot_warmup`` pre-lowers
    and compiles every ``(precision, guided, refresh)`` step variant the
    request mix can reach (plus the fixed-shape helpers) WITHOUT running
    a tick, populating the persistent cache ahead of time.  Warmup wall
    time and the time-to-first-served-tick are recorded in the metrics
    (``warmup_s`` / ``first_tick_s``).
  * Overload: give the engine a bounded ``AdmissionQueue(max_depth=...,
    shed_policy='deadline-aware')`` and excess arrivals are shed instead
    of growing the backlog; at admission the engine expires queued
    requests whose deadline already passed, so a dead request never
    occupies a slot.  Sheds are tallied by cause in the metrics, along
    with p50/p99 queue wait and the peak queue depth.

Sharded multi-device serving (``mesh=...``): the slot axis shards over
the mesh's ``data`` axis, so ONE engine spans an N-device mesh with each
device carrying ``slots / N`` slot rows of the latent / x0 / DeepCache
buffers.  Every step variant is jitted with sharded ``out_shardings``
(donated buffers stay resident and partitioned across ticks) and pins
its layout with ``distributed.sharding.shard_hint``; ``_place`` /
``_take`` move single samples in and out of the sharded buffers without
ever materializing the whole buffer on one device.  The slot axis is
pure data parallelism — the UNet treats batch rows independently — so a
request served on the mesh is bitwise identical to the single-device
engine.  Three things ride on top:

  * **Decode overlap** (``overlap_decode``, default on when sharded):
    draining a finished slot *dispatches* the VAE decode asynchronously
    and frees the slot immediately; the image materializes only after
    the NEXT denoise tick has been launched, so decode runs behind the
    following step instead of serializing with it.  Results surface one
    tick later (a final flush covers the last tick); the metrics count
    ``overlapped_decodes``.
  * **Elastic resize** (``elastic_resize``): when devices drop or
    rejoin, ``distributed.fault_tolerance.elastic_serving_plan`` sizes
    the new 1-D mesh and the engine rebuilds its slot buffer on it at a
    constant per-device slot budget, re-placing in-flight latents and
    *parking* any overflow on the host (parked requests re-enter slots
    as they free, ahead of the queue, with a forced cache refresh).
    Step variants are re-lowered for the new topology — ``aot_warmup``
    pre-compiles them without serving a tick — and a ``StepMonitor``
    (``engine.monitor``) keeps per-device tick timings so a deployment
    can trigger the resize from straggler reports.
  * **AOT warmup / persistent cache** carry through: the pre-lowered
    shapes are tagged with the mesh sharding, so the executables a
    sharded engine persists are the ones it serves with.

Output equivalence: with eta=0 DDIM is deterministic given the initial
noise, and both the UNet and the per-row w8a8 activation scales treat
batch elements independently, so a request served through the engine —
at fp32 OR w8a8 — is numerically identical to running
``DiffusionPipeline.generate(key=PRNGKey(seed), batch=1, steps=s,
policy=...)`` on its own (tests pin this at atol 1e-5).  ``w8a8+noise``
is deterministic under the engine's noise seed: two engines with the same
seed and request sequence produce identical images.

Observability (``repro.obs``): construct with ``tracer=Tracer()`` and
the engine records every request's lifecycle — submit, shed (with the
specific victim, via the queue's ``on_shed`` hook), slot assignment,
one span per step dispatch tagged (precision, refresh|skip, guided)
with its PhotonicAccountant energy delta, early exit, decode dispatch /
overlapped completion, and a submit-to-finish request span stamped from
the SAME timing fields the metrics use (so trace and metrics reconcile
exactly) — plus engine-global events (warmup, AOT lowering, elastic
resize, straggler flags) and a per-tick occupancy counter.  The default
is the no-op ``NULL_TRACER``; every hot-path hook guards on
``tracer.enabled``, so an untraced engine builds no event objects.
``on_straggler=`` registers a callback the ``StepMonitor`` fires when
its flagged-device set changes — the hook a deployment uses to trigger
``elastic_resize`` from measured straggle instead of a fixed schedule.
``engine.reporter`` (a ``SnapshotReporter``) emits periodic in-run
metric lines, checked once per tick.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as PSpec

from repro.core.precision import PrecisionPolicy
from repro.diffusion import samplers
from repro.diffusion.deepcache import unet_apply_cached
from repro.diffusion.pipeline import DiffusionPipeline
from repro.distributed.fault_tolerance import (StepMonitor,
                                               elastic_serving_plan)
from repro.distributed.sharding import named, shard_hint
from repro.models import autoencoder as AE
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.serving.api import GenerationRequest, GenerationResult
from repro.serving.batcher import align_slots, plan_tick
from repro.serving.compile_cache import trim_cache
from repro.serving.metrics import PhotonicAccountant, ServingMetrics
from repro.serving.queue import AdmissionQueue, Queued


@dataclasses.dataclass
class _Active:
    """One occupied slot: the request plus its trajectory cursor and the
    scheduler state (resolved cache/early-exit knobs, eval counters)."""
    request: GenerationRequest
    ts: np.ndarray               # this request's DDIM timestep trajectory
    i: int                       # next step index into `ts`
    submit_time: float
    start_time: float
    cache_on: bool = False       # participates in the shared refresh cadence
    exit_tol: float = 0.0        # <= 0: early exit disabled
    exit_patience: int = 2
    full_evals: int = 0          # full-UNet ticks consumed so far
    cached_evals: int = 0        # shallow (skip) ticks consumed so far
    exit_streak: int = 0         # consecutive ticks under exit_tol
    force_refresh: bool = False  # next tick must be a full pass (set when
    #                              a parked slot re-enters: its DeepCache
    #                              feature rows did not survive the resize)


@dataclasses.dataclass
class _Pending:
    """A drained slot whose VAE decode has been dispatched but not
    materialized: under decode overlap the image syncs only after the
    NEXT tick's UNet step is in flight (``_finish_drain``)."""
    active: _Active
    z: 'jax.Array'               # decoded (or raw-latent) batch-1 array
    now: float
    wall_clock: bool
    early: bool
    slot: int = -1               # slot the request drained from (tracing)


class ContinuousBatchingEngine:
    def __init__(self, pipe: DiffusionPipeline, slots: int = 4,
                 context=None, queue: Optional[AdmissionQueue] = None,
                 metrics: Optional[ServingMetrics] = None,
                 photonic: Optional[PhotonicAccountant] = None,
                 track_energy: bool = True,
                 noise_model=None, noise_seed: int = 0,
                 quality_probe: int = 1,
                 cache_interval: int = 1,
                 exit_tol: Optional[float] = None,
                 exit_patience: int = 2,
                 exit_min_steps: int = 2,
                 mesh: Optional[Mesh] = None,
                 slots_per_device: Optional[int] = None,
                 overlap_decode: Optional[bool] = None,
                 tracer: Optional[Tracer] = None,
                 on_straggler=None,
                 reporter=None):
        """``noise_model`` / ``noise_seed`` configure the ``w8a8+noise``
        policy (defaults: the paper's analog perturbation model, seed 0).
        ``quality_probe``: run the full-step fp32 reference + PSNR/MSE
        probe for every k-th completed quantized / cached / early-exited
        request (0 disables probing).

        ``cache_interval``: the shared DeepCache refresh cadence — a full
        UNet pass every ``cache_interval`` ticks, shallow passes in
        between (1 = caching off).  ``exit_tol`` / ``exit_patience``:
        engine-wide speculative early-exit defaults (requests override
        per field; ``exit_tol=None`` leaves early exit off).
        ``exit_min_steps``: never early-exit before this many executed
        steps (at least 2 — the convergence signal needs two x0
        predictions).

        ``mesh``: a 1-D ``('data',)`` mesh (``launch.mesh.serving_mesh``)
        shards the slot axis of every buffer across its devices.
        ``slots_per_device`` overrides ``slots`` with a per-device budget
        (the invariant ``elastic_resize`` preserves); otherwise ``slots``
        is rounded up to divide the mesh.  ``overlap_decode`` (default:
        on exactly when sharded) pipelines drained requests' VAE decodes
        behind the next denoise tick.

        ``tracer``: a ``repro.obs.Tracer`` recording the lifecycle /
        engine event stream (default: the zero-cost ``NULL_TRACER``).
        ``on_straggler``: callback fired with a ``StragglerReport``
        whenever the ``StepMonitor``'s flagged-device set changes.
        ``reporter``: a ``repro.obs.SnapshotReporter`` polled once per
        tick for periodic in-run metric lines."""
        if slots < 1:
            raise ValueError('need at least one slot')
        if cache_interval < 1:
            raise ValueError('cache_interval must be >= 1')
        self._created = time.perf_counter()   # time-to-first-tick origin
        self.pipe = pipe
        self.mesh = mesh
        if mesh is not None:
            if 'data' not in mesh.axis_names:
                raise ValueError("serving mesh needs a 'data' axis")
            ndev = int(mesh.shape['data'])
            if slots_per_device is not None:
                if slots_per_device < 1:
                    raise ValueError('slots_per_device must be >= 1')
                slots = slots_per_device * ndev
            else:
                slots = align_slots(slots, ndev)
            self._slots_per_device = slots // ndev
            self.monitor = StepMonitor(n_hosts=ndev)
        else:
            self._slots_per_device = slots
            self.monitor = None
        self.slots = slots
        self.overlap_decode = (mesh is not None) if overlap_decode is None \
            else bool(overlap_decode)
        self.context = context
        # `is not None`, not truthiness: an empty AdmissionQueue is falsy
        # (len() == 0), and `or` would silently drop its depth bound
        self.queue = queue if queue is not None else AdmissionQueue()
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.on_straggler = on_straggler
        self.reporter = reporter
        self._straggler_flagged: Tuple[int, ...] = ()
        # shed attribution rides the queue's per-request hook (chained if
        # the caller installed one): the queue knows WHICH request each
        # shed dropped, so metrics and trace carry the victim's id
        self._user_on_shed = self.queue.on_shed
        self.queue.on_shed = self._queue_shed
        if mesh is not None:
            self.metrics.devices = int(mesh.shape['data'])
        self.photonic = photonic or (
            PhotonicAccountant(pipe.unet_cfg) if track_energy else None)
        self.noise_model = noise_model
        self.noise_seed = noise_seed
        self.quality_probe = quality_probe
        self.cache_interval = cache_interval
        self.exit_tol = exit_tol
        self.exit_patience = exit_patience
        self.exit_min_steps = max(2, exit_min_steps)
        cfg = pipe.unet_cfg
        self._sample_shape = (cfg.img_size, cfg.img_size, cfg.in_ch)
        # slot-axis sharding of every (slots, ...) buffer; None when
        # single-device.  Rebuilt (with the buffers and every jitted fn
        # whose out_shardings pin it) by elastic_resize.
        self._shard = None if mesh is None else named(mesh, PSpec('data'))
        self.x = self._zeros_buf((slots,) + self._sample_shape)
        # previous-tick x0 predictions (the early-exit convergence signal)
        self.x0 = self._zeros_buf((slots,) + self._sample_shape)
        self._slot: List[Optional[_Active]] = [None] * slots
        self._pending: List[_Pending] = []   # decode-overlap in flight
        # requests displaced by an elastic shrink: (active, x row, x0 row)
        # host triples, re-admitted ahead of the queue as slots free
        self._parked: List[Tuple[_Active, np.ndarray, np.ndarray]] = []
        self._tick_s: Optional[float] = None  # measured service rate
        self._traj: Dict[int, np.ndarray] = {}
        self._wall_t0 = 0.0          # wall-clock origin (set by replay)
        self._probe_done = 0         # completed probe-eligible requests
        self._phase = 0              # shared refresh cadence position
        # precision machinery: policies and jitted steps are built lazily,
        # one step per (precision, guided) pair — plus, with caching on,
        # exactly one (refresh, skip) pair per (precision, guided) — each
        # closing over its frozen PrecisionPolicy; new policies never
        # disturb compiled ones
        self._policies: Dict[str, PrecisionPolicy] = {}
        self._steps: Dict[Tuple[str, bool], 'jax.stages.Wrapped'] = {}
        self._csteps: Dict[Tuple[str, bool, bool], 'jax.stages.Wrapped'] = {}
        self._zero_key = jax.random.PRNGKey(0)     # inert key, fp32/w8a8

        # slot-axis DeepCache buffers: the activation entering the last up
        # level, one row per slot (shape discovered by abstract evaluation
        # of the refresh pass — policies don't change it)
        self._cache_c = self._cache_u = None
        self._cache_row = None       # (row shape, dtype) for resize rebuilds
        if self.cache_interval > 1:
            cache_s = jax.eval_shape(
                lambda xx, tt: unet_apply_cached(
                    pipe.unet_params, cfg, xx, tt, None, True,
                    self.context, PrecisionPolicy.fp32()),
                jax.ShapeDtypeStruct((slots,) + self._sample_shape,
                                     jnp.float32),
                jax.ShapeDtypeStruct((slots,), jnp.int32))[1]
            self._cache_row = (tuple(cache_s.shape[1:]), cache_s.dtype)
            self._cache_c = self._zeros_buf(cache_s.shape, cache_s.dtype)
            if self.context is not None:
                # classifier-free guidance caches the unconditional
                # branch's deep features separately
                self._cache_u = self._zeros_buf(cache_s.shape, cache_s.dtype)

        self._build_helpers()

    def _zeros_buf(self, shape, dtype=jnp.float32):
        """A zero (slots, ...) buffer, placed sharded over the mesh's
        ``data`` axis when the engine is sharded."""
        buf = jnp.zeros(shape, dtype)
        if self._shard is not None:
            buf = jax.device_put(buf, self._shard)
        return buf

    def _build_helpers(self) -> None:
        """(Re)build the fixed-shape jitted helpers.  ``_place`` pins its
        output to the slot sharding so single-sample writes never gather
        the buffer onto one device.  Called at construction and again by
        ``elastic_resize`` — ``out_shardings`` captures the mesh, so a
        topology change must re-create the wrapped functions."""
        pipe = self.pipe
        # initial noise exactly as ddim_sample: x = normal(split(key)[0], .)
        self._init_noise = jax.jit(lambda key: jax.random.normal(
            jax.random.split(key)[0], (1,) + self._sample_shape)[0])
        if self._shard is not None:
            self._place = jax.jit(lambda x, i, v: x.at[i].set(v),
                                  out_shardings=self._shard)
        else:
            self._place = jax.jit(lambda x, i, v: x.at[i].set(v))
        self._take = jax.jit(lambda x, i: x[i])
        if pipe.vae_params is not None:
            self._decode = jax.jit(lambda z: AE.vae_decode(
                pipe.vae_params, pipe.vae_cfg, z))
        else:
            self._decode = None

    # -- precision machinery ------------------------------------------------
    def _policy_for(self, name: str) -> PrecisionPolicy:
        """Resolve a request's precision name to this engine's policy."""
        if name not in self._policies:
            if name == 'fp32':
                pol = PrecisionPolicy.fp32()
            elif name == 'w8a8':
                cal = self.pipe.policy.calibration \
                    if self.pipe.policy.quantized else 'dynamic'
                pol = PrecisionPolicy.w8a8(calibration=cal)
            else:  # 'w8a8+noise' (request validation guarantees the name)
                pol = PrecisionPolicy.w8a8_noise(
                    model=self.noise_model, noise_seed=self.noise_seed)
            self._policies[name] = pol
        return self._policies[name]

    @staticmethod
    def _finish_step(sched, eps, x, x0p, t, t_prev, active):
        """Shared tail of every step variant: DDIM update + x0 tracking.

        Returns (x_out, x0_out, delta) where ``delta`` is the per-slot
        relative x0 movement ``||x0_t - x0_{t-1}|| / ||x0_{t-1}||``
        (RMS over sample dims; 0 for inactive slots) — the speculative
        early-exit convergence signal."""
        x_new, x0_new = samplers.ddim_step(sched, eps, x, t, t_prev,
                                           return_x0=True)
        axes = tuple(range(1, x.ndim))
        num = jnp.sqrt(jnp.mean((x0_new - x0p) ** 2, axis=axes))
        den = jnp.sqrt(jnp.mean(x0p ** 2, axis=axes)) + 1e-8
        delta = jnp.where(active, num / den, 0.0)
        mask = active.reshape((-1,) + (1,) * (x.ndim - 1))
        return (jnp.where(mask, x_new, x), jnp.where(mask, x0_new, x0p),
                delta)

    def _make_step(self, pol: PrecisionPolicy, use_guidance: bool):
        pipe, sched, mesh = self.pipe, self.pipe.sched, self.mesh

        def step(x, x0p, t, t_prev, active, guidance, key):
            if mesh is not None:
                # pin the slot axis to the data axis so XLA never inserts
                # a gather: the whole step stays row-parallel
                x = shard_hint(x, 'data', mesh=mesh)
                x0p = shard_hint(x0p, 'data', mesh=mesh)
            nkey = key if pol.noisy else None
            if use_guidance:
                # per-slot classifier-free guidance: blend against the
                # unconditional eps only for guided slots.  Under a noisy
                # policy the unconditional pass draws independent noise.
                ukey = jax.random.fold_in(key, 1) if pol.noisy else None
                eps_c = pipe._eps_fn(self.context, 0.0, policy=pol,
                                     noise_key=nkey)(x, t)
                eps_u = pipe._eps_fn(None, 0.0, policy=pol,
                                     noise_key=ukey)(x, t)
                g = guidance.reshape((-1,) + (1,) * (x.ndim - 1))
                eps = jnp.where(g > 0, eps_u + g * (eps_c - eps_u), eps_c)
            else:
                eps = pipe._eps_fn(self.context, 0.0, policy=pol,
                                   noise_key=nkey)(x, t)
            return self._finish_step(sched, eps, x, x0p, t, t_prev, active)
        return step

    def _make_cached_step(self, pol: PrecisionPolicy, use_guidance: bool,
                          refresh: bool):
        """DeepCache-phased step: ``refresh`` is STATIC (two jitted
        variants per (policy, guided) pair, matching the interval
        schedule).  The refresh variant rewrites the cache rows of the
        slots it ran; the skip variant reuses them via the shallow pass
        and leaves the buffers untouched."""
        pipe, sched, cfg = self.pipe, self.pipe.sched, self.pipe.unet_cfg
        params = pipe.unet_params
        mesh = self.mesh

        def pin(*bufs):
            if mesh is None:
                return bufs
            return tuple(shard_hint(b, 'data', mesh=mesh) for b in bufs)

        def eval_cached(x, t, cache, context, nkey):
            return unet_apply_cached(params, cfg, x, t, cache, refresh,
                                     context, pol, noise_key=nkey)

        if use_guidance:
            def step(x, x0p, cache_c, cache_u, t, t_prev, active,
                     guidance, key):
                x, x0p, cache_c, cache_u = pin(x, x0p, cache_c, cache_u)
                nkey = key if pol.noisy else None
                ukey = jax.random.fold_in(key, 1) if pol.noisy else None
                eps_c, new_c = eval_cached(x, t, cache_c, self.context, nkey)
                eps_u, new_u = eval_cached(x, t, cache_u, None, ukey)
                g = guidance.reshape((-1,) + (1,) * (x.ndim - 1))
                eps = jnp.where(g > 0, eps_u + g * (eps_c - eps_u), eps_c)
                x_out, x0_out, delta = self._finish_step(
                    sched, eps, x, x0p, t, t_prev, active)
                if refresh:
                    cm = active.reshape((-1,) + (1,) * (new_c.ndim - 1))
                    cache_c = jnp.where(cm, new_c, cache_c)
                    cache_u = jnp.where(cm, new_u, cache_u)
                return x_out, x0_out, delta, cache_c, cache_u
        else:
            def step(x, x0p, cache_c, t, t_prev, active, guidance, key):
                x, x0p, cache_c = pin(x, x0p, cache_c)
                nkey = key if pol.noisy else None
                eps, new_c = eval_cached(x, t, cache_c, self.context, nkey)
                x_out, x0_out, delta = self._finish_step(
                    sched, eps, x, x0p, t, t_prev, active)
                if refresh:
                    cm = active.reshape((-1,) + (1,) * (new_c.ndim - 1))
                    cache_c = jnp.where(cm, new_c, cache_c)
                return x_out, x0_out, delta, cache_c
        return step

    def _get_step(self, precision: str, guided: bool):
        k = (precision, guided)
        if k not in self._steps:
            pol = self._policy_for(precision)
            kw = {} if self._shard is None else {
                'out_shardings': (self._shard,) * 3}
            self._steps[k] = jax.jit(self._make_step(pol, guided),
                                     donate_argnums=(0, 1), **kw)
        return self._steps[k]

    def _get_cached_step(self, precision: str, guided: bool, refresh: bool):
        k = (precision, guided, refresh)
        if k not in self._csteps:
            pol = self._policy_for(precision)
            donate = (0, 1, 2, 3) if guided else (0, 1, 2)
            n_out = 5 if guided else 4
            kw = {} if self._shard is None else {
                'out_shardings': (self._shard,) * n_out}
            self._csteps[k] = jax.jit(
                self._make_cached_step(pol, guided, refresh),
                donate_argnums=donate, **kw)
        return self._csteps[k]

    def _tick_key(self, pol: PrecisionPolicy, tick_idx: int):
        """Per-tick analog-noise key: the policy's seed anchor folded with
        the tick index, so draws vary along every trajectory yet the whole
        serving run is deterministic under (seed, request sequence)."""
        if not pol.noisy:
            return self._zero_key
        return jax.random.fold_in(
            jax.random.PRNGKey(pol.noise_seed), tick_idx)

    # -- introspection -----------------------------------------------------
    @property
    def active_count(self) -> int:
        return sum(a is not None for a in self._slot)

    @property
    def busy(self) -> bool:
        return (self.active_count > 0 or len(self.queue) > 0
                or bool(self._pending) or bool(self._parked))

    @property
    def tick_s_estimate(self) -> Optional[float]:
        """Measured steady-state seconds per tick (None until
        ``measure_tick_s`` runs, settable so deployments can pin it).
        Feeds the admission-time SLO margin: a queued request whose
        deadline lands inside its own estimated service time is shed at
        admission instead of burning slot time on a guaranteed miss."""
        return self._tick_s

    @tick_s_estimate.setter
    def tick_s_estimate(self, value: Optional[float]) -> None:
        self._tick_s = None if value is None else float(value)

    def _service_margin_s(self, req: GenerationRequest) -> float:
        """Estimated service time were ``req`` admitted right now — the
        expiry margin.  One engine tick advances every in-flight request
        one step, so a request needs ~``steps`` ticks of residence.  0
        (expire only already-dead entries) until a tick estimate exists."""
        if self._tick_s is None:
            return 0.0
        return req.steps * self._tick_s

    def compile_stats(self) -> Dict[str, int]:
        """Per-jitted-function compile counts (cache sizes).  Constant
        after one warmup per served policy == zero recompilation.  Step
        entries are labeled ``_step`` / ``_step_guided`` for fp32 and
        ``_step[w8a8]``-style for quantized policies; the DeepCache pair
        appears as ``_step_refresh`` / ``_step_skip`` variants."""
        out = {}
        for (pname, guided), fn in self._steps.items():
            label = ('_step_guided' if guided else '_step') + (
                '' if pname == 'fp32' else f'[{pname}]')
            out[label] = self._cache_size(fn)
        for (pname, guided, refresh), fn in self._csteps.items():
            label = ('_step_refresh' if refresh else '_step_skip') + (
                '_guided' if guided else '') + (
                '' if pname == 'fp32' else f'[{pname}]')
            out[label] = self._cache_size(fn)
        for name in ('_init_noise', '_place', '_take', '_decode'):
            fn = getattr(self, name)
            if fn is None:
                continue
            out[name] = self._cache_size(fn)
        return out

    @staticmethod
    def _cache_size(fn) -> int:
        try:
            return int(fn._cache_size())
        except Exception:                          # pragma: no cover
            return -1

    # -- observability -----------------------------------------------------
    #: queue shed causes -> the metrics ledger's reason names
    _SHED_REASONS = {'rejected': 'queue_full', 'evicted': 'deadline_evict',
                     'expired': 'expired'}

    def _queue_shed(self, reason: str, req: GenerationRequest,
                    now: float) -> None:
        """Per-request shed hook the ``AdmissionQueue`` fires: tally the
        cause in the metrics and attribute the shed to its request id in
        the trace."""
        self.metrics.record_shed(self._SHED_REASONS.get(reason, reason))
        if self.tracer.enabled:
            self.tracer.instant('shed', cat='queue', ts=now,
                                rid=req.request_id,
                                reason=self._SHED_REASONS.get(reason, reason),
                                trace_id=req.effective_trace_id)
        if self._user_on_shed is not None:
            self._user_on_shed(reason, req, now)

    def _slot_device(self, idx: int) -> Optional[int]:
        """Mesh device carrying slot row ``idx`` (None single-device)."""
        if self.mesh is None:
            return None
        return idx // self._slots_per_device

    def _step_energy_j(self, precision: str, refresh: bool,
                       guided: bool) -> float:
        """Energy one slot consumes in one tick at (precision, refresh
        kind) — the per-event delta step trace events carry.  Rides the
        accountant's simulation cache, so per-tick cost is a dict hit."""
        if self.photonic is None:
            return 0.0
        full, cached = (1, 0) if refresh else (0, 1)
        energy_j, _ = self.photonic.energy_evals(full, cached, guided,
                                                 precision=precision)
        return energy_j

    def _poll_straggler(self):
        """Check the ``StepMonitor`` and, when its flagged-device set
        CHANGES, emit a straggler trace event and fire ``on_straggler``
        (edge-triggered so a persistent straggler doesn't refire every
        tick).  Returns the current report (None when clean)."""
        if self.monitor is None:
            return None
        report = self.monitor.check()
        flagged = tuple(report.slow_hosts) if report is not None else ()
        if flagged and flagged != self._straggler_flagged:
            self.tracer.instant('straggler', cat='engine',
                                slow_devices=list(flagged),
                                median_s=report.median_s,
                                threshold_s=report.threshold_s,
                                recommendation=report.recommendation)
            if self.on_straggler is not None:
                self.on_straggler(report)
        self._straggler_flagged = flagged
        return report

    # -- request flow ------------------------------------------------------
    def submit(self, req: GenerationRequest,
               now: Optional[float] = None) -> bool:
        now = time.perf_counter() if now is None else now
        # sheds (rejected arrival / evicted entry) are recorded by the
        # queue's on_shed hook with the specific victim request
        ok = self.queue.submit(req, now)
        if ok:
            self.metrics.record_submit(now)
            if self.tracer.enabled:
                self.tracer.instant('submit', cat='queue', ts=now,
                                    rid=req.request_id,
                                    steps=req.steps,
                                    precision=req.precision,
                                    trace_id=req.effective_trace_id)
        self.metrics.observe_queue_depth(len(self.queue))
        return ok

    def _trajectory(self, steps: int) -> np.ndarray:
        if steps not in self._traj:
            self._traj[steps] = samplers.ddim_timesteps(
                self.pipe.sched, steps)
        return self._traj[steps]

    def _cached_active(self) -> int:
        return sum(a is not None and a.cache_on for a in self._slot)

    def _unpark(self, idx: int) -> None:
        """Re-admit the oldest parked request into free slot ``idx``:
        restore its latent and x0 rows from the host copies.  DeepCache
        feature rows are NOT parked (their shape differs from the sample
        shape, and a resize changes their buffer anyway), so a
        cache-enabled request re-enters with ``force_refresh`` — its
        first tick back is a full pass that rewrites the rows."""
        a, hx, hx0 = self._parked.pop(0)
        self.x = self._place(self.x, jnp.int32(idx), jnp.asarray(hx))
        self.x0 = self._place(self.x0, jnp.int32(idx), jnp.asarray(hx0))
        if a.cache_on:
            a.force_refresh = True
        self._slot[idx] = a
        if self.tracer.enabled:
            self.tracer.instant('unpark', cat='queue',
                                rid=a.request.request_id, slot=idx,
                                device=self._slot_device(idx),
                                step_index=a.i)

    def _admit(self, now: float) -> None:
        # expire whenever ANY queued entry carries a deadline — the SLO
        # is a property of the request, not of the shed policy, so a
        # dead request must never occupy a slot under 'reject-newest' or
        # an unbounded queue either.  The margin folds in the estimated
        # service time: a request that would only FINISH past its
        # deadline is equally dead at admission time.
        if getattr(self.queue, 'has_deadlines', False):
            # expired entries tally + trace through the queue's on_shed
            self.queue.expire(now, margin_s=self._service_margin_s)
        # parked (resize-displaced) requests re-enter ahead of the queue;
        # force_refresh lets them rejoin mid-cadence (a mixed tick)
        for idx in range(self.slots):
            if not self._parked:
                break
            if self._slot[idx] is None:
                self._unpark(idx)
        if self.cache_interval > 1:
            if self._cached_active() == 0:
                # nothing riding the cadence: re-anchor it so admission
                # is never delayed on an idle engine
                self._phase = 0
            if self._phase != 0 and self.queue.peek() is not None:
                # phase-aligned admission: hold queued requests until the
                # next refresh tick so every skip tick stays a whole-batch
                # shallow pass (the phase-alignment invariant)
                return
        for idx in range(self.slots):
            if self._slot[idx] is not None:
                continue
            q = self.queue.pop()
            if q is None:
                return
            req = q.request
            interval = self.cache_interval if req.cache_interval is None \
                else req.cache_interval
            tol = self.exit_tol if req.exit_tol is None else req.exit_tol
            patience = self.exit_patience if req.exit_patience is None \
                else req.exit_patience
            self._slot[idx] = _Active(
                request=req, ts=self._trajectory(req.steps), i=0,
                submit_time=q.enqueue_time, start_time=now,
                cache_on=self.cache_interval > 1 and interval > 1,
                exit_tol=0.0 if tol is None else float(tol),
                exit_patience=patience)
            if self.tracer.enabled:
                self.tracer.instant('slot_assign', cat='queue', ts=now,
                                    rid=req.request_id, slot=idx,
                                    device=self._slot_device(idx),
                                    queue_wait_s=now - q.enqueue_time)
            noise = self._init_noise(jax.random.PRNGKey(req.seed))
            self.x = self._place(self.x, jnp.int32(idx), noise)
            # seed the x0 tracker with the slot's noise: the first delta
            # is meaningless and ignored (exit_min_steps >= 2)
            self.x0 = self._place(self.x0, jnp.int32(idx), noise)

    def _fp32_reference(self, req: GenerationRequest,
                        guided: bool) -> np.ndarray:
        """Eager fp32 generation for the same seed/steps/guidance — the
        quality probe's reference image (context row 0 stands in for the
        engine's shared conditioning)."""
        ctx = self.context[:1] if (guided and self.context is not None) \
            else None
        ref = self.pipe.generate(
            jax.random.PRNGKey(req.seed), batch=1, steps=req.steps,
            context=ctx, guidance=req.guidance if guided else 0.0,
            policy=PrecisionPolicy.fp32())
        return np.asarray(ref[0])

    @staticmethod
    def _quality(image: np.ndarray, ref: np.ndarray):
        """(mse, psnr_db) of the served image vs the fp32 reference."""
        mse = float(np.mean((image.astype(np.float64) -
                             ref.astype(np.float64)) ** 2))
        rng = float(ref.max() - ref.min()) or 1.0
        psnr = math.inf if mse <= 0.0 else 10.0 * math.log10(rng * rng / mse)
        return mse, psnr

    def _begin_drain(self, idx: int, now: float,
                     wall_clock: bool = False,
                     early: bool = False) -> _Pending:
        """Dispatch a finished slot's VAE decode and free the slot.
        Dispatch only — no device sync — so under decode overlap the
        decode executes behind the next tick's UNet step and the slot is
        refillable immediately; ``_finish_drain`` pays the sync."""
        a = self._slot[idx]
        # an early-exit drain commits the CONVERGED x0 prediction — the
        # speculative clean image — instead of the partially-denoised x
        z = self._take(self.x0 if early else self.x, jnp.int32(idx))[None]
        if self._decode is not None:
            z = self._decode(z)
        self._slot[idx] = None
        if self.tracer.enabled:
            if early:
                self.tracer.instant('early_exit', cat='request', ts=now,
                                    rid=a.request.request_id, slot=idx,
                                    device=self._slot_device(idx),
                                    steps_executed=a.i,
                                    steps_requested=a.request.steps)
            self.tracer.instant('decode_dispatch', cat='decode', ts=now,
                                rid=a.request.request_id, slot=idx,
                                device=self._slot_device(idx))
        return _Pending(active=a, z=z, now=now, wall_clock=wall_clock,
                        early=early, slot=idx)

    def _finish_drain(self, p: _Pending,
                      overlapped: bool = False) -> GenerationResult:
        """Materialize a dispatched drain: device sync, latency stamp,
        energy + quality accounting, completion metrics.  ``overlapped``
        marks a decode that hid behind the following tick's UNet step."""
        a, z, now, wall_clock, early = (p.active, p.z, p.now,
                                        p.wall_clock, p.early)
        req = a.request
        pol = self._policy_for(req.precision)
        guided = req.guidance > 0.0 and self.context is not None
        energy_j = epb = 0.0
        if self.photonic is not None:
            # skip ticks are billed through the DeepCache workload
            # transform (shallow fraction of a full-UNet tick); early
            # exit pays only for the ticks that actually ran
            energy_j, epb = self.photonic.energy_evals(
                a.full_evals, a.cached_evals, guided,
                precision=req.precision)
        image = np.asarray(z[0])           # device sync: image materialized
        if wall_clock:
            # only now has the final step + decode actually executed
            now = time.perf_counter() - self._wall_t0
        # quality probe AFTER the latency stamp: the eager fp32 reference
        # is measurement apparatus, not served work.  Cached or
        # early-exited requests are probe-eligible at ANY precision —
        # their PSNR vs the full-step fp32 reference is the equal-quality
        # axis of the throughput frontier.
        mse = psnr = None
        reduced = early or a.cached_evals > 0
        if (pol.quantized or reduced) and self.quality_probe > 0:
            if self._probe_done % self.quality_probe == 0:
                mse, psnr = self._quality(
                    image, self._fp32_reference(req, guided))
            self._probe_done += 1
        res = GenerationResult(
            request_id=req.request_id, image=image,
            steps=req.steps, submit_time=a.submit_time,
            start_time=a.start_time, finish_time=now,
            energy_j=energy_j, epb_pj=epb,
            precision=req.precision, policy=pol,
            quality_psnr_db=psnr, quality_mse=mse,
            steps_executed=a.i, full_evals=a.full_evals,
            cached_evals=a.cached_evals, early_exit=early,
            trace_id=req.effective_trace_id)
        self.metrics.record_complete(res, slo_ms=req.slo_ms)
        if self.tracer.enabled:
            self.tracer.instant('decode_done', cat='decode', ts=now,
                                rid=req.request_id, slot=p.slot,
                                device=self._slot_device(p.slot),
                                overlapped=overlapped)
            # the request span is stamped from the RESULT's own timing
            # fields, so trace latency == metrics latency exactly
            self.tracer.complete(
                'request', a.submit_time, now, cat='request',
                rid=req.request_id, slot=p.slot,
                device=self._slot_device(p.slot),
                trace_id=res.trace_id, precision=req.precision,
                steps_executed=a.i, full_evals=a.full_evals,
                cached_evals=a.cached_evals, early_exit=early,
                queue_wait_s=res.queue_delay_s, energy_j=energy_j,
                slo_ms=req.slo_ms)
            self.tracer.instant('complete', cat='request', ts=now,
                                rid=req.request_id, slot=p.slot,
                                latency_s=res.latency_s)
        return res

    def _flush_pending(self, overlapped: bool) -> List[GenerationResult]:
        """Materialize every in-flight decode.  ``overlapped=True`` when
        a UNet step was dispatched between the decode dispatch and this
        sync (the decode actually hid behind compute)."""
        if not self._pending:
            return []
        pending, self._pending = self._pending, []
        if overlapped:
            self.metrics.record_overlapped_decode(len(pending))
        return [self._finish_drain(p, overlapped=overlapped)
                for p in pending]

    def tick(self, now: Optional[float] = None,
             wall_clock: Optional[bool] = None) -> List[GenerationResult]:
        """Admit (phase-aligned when caching) -> one mixed-timestep UNet
        step per (precision group, refresh|skip) pair -> drain finished
        and converged slots.

        ``wall_clock`` (default: `now` not given) makes drained results
        re-stamp their finish time after the device sync, so reported
        latencies include the final step + VAE decode.

        Under decode overlap a finished request's result surfaces on the
        FOLLOWING tick (its decode materializes after that tick's step
        is dispatched); an idle tick flushes the stragglers."""
        wall_clock = (now is None) if wall_clock is None else wall_clock
        now = time.perf_counter() - self._wall_t0 if now is None else now
        t_tick0 = time.perf_counter()
        self._admit(now)
        if self.active_count == 0:
            # nothing to step: materialize leftover overlapped decodes
            # (no compute to hide behind, so not counted as overlapped)
            return self._flush_pending(overlapped=False)
        caching = self.cache_interval > 1
        refresh_tick = self._phase == 0
        t = np.zeros(self.slots, np.int32)
        t_prev = np.full(self.slots, -1, np.int32)
        guidance = np.zeros(self.slots, np.float32)
        needs_refresh = np.ones(self.slots, bool)
        track_exit = False
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            t[idx] = a.ts[a.i]
            t_prev[idx] = a.ts[a.i + 1] if a.i + 1 < len(a.ts) else -1
            guidance[idx] = a.request.guidance
            needs_refresh[idx] = ((not a.cache_on) or a.i == 0
                                  or refresh_tick or a.force_refresh)
            if a.exit_tol > 0.0 and a.i + 1 >= self.exit_min_steps:
                track_exit = True
        plan = plan_tick(
            [a.request.precision if a is not None else None
             for a in self._slot],
            needs_refresh, caching)
        tick_idx = self.metrics.ticks
        active_mask = np.zeros(self.slots, bool)
        for _, _, m in plan:
            active_mask |= m
        self.metrics.record_tick(
            int(active_mask.sum()),
            full_slots=int((active_mask & needs_refresh).sum()),
            cached_slots=int((active_mask & ~needs_refresh).sum()))
        had_cached = self._cached_active() > 0
        # one pre-compiled masked step per plan entry — (precision group,
        # refresh|skip) submask; donated latent/x0/cache buffers chain
        # call to call, so slots outside the running submask pass through
        # untouched
        traced = self.tracer.enabled
        delta_parts = []
        t_d, tp_d = jnp.asarray(t), jnp.asarray(t_prev)
        for pname, refresh, m in plan:
            g = np.where(m, guidance, 0.0).astype(np.float32)
            guided = self.context is not None and bool(g.any())
            key = self._tick_key(self._policy_for(pname), tick_idx)
            m_d, g_d = jnp.asarray(m), jnp.asarray(g)
            t_step0 = self.tracer.now() if traced else 0.0
            if caching:
                step_fn = self._get_cached_step(pname, guided,
                                                refresh=refresh)
                if guided:
                    (self.x, self.x0, d, self._cache_c,
                     self._cache_u) = step_fn(
                        self.x, self.x0, self._cache_c, self._cache_u,
                        t_d, tp_d, m_d, g_d, key)
                else:
                    self.x, self.x0, d, self._cache_c = step_fn(
                        self.x, self.x0, self._cache_c,
                        t_d, tp_d, m_d, g_d, key)
            else:
                step_fn = self._get_step(pname, guided)
                self.x, self.x0, d = step_fn(
                    self.x, self.x0, t_d, tp_d, m_d, g_d, key)
            delta_parts.append((m, d))
            if traced:
                n_m = int(m.sum())
                self.tracer.complete(
                    'step', t_step0, self.tracer.now(), cat='tick',
                    tick=tick_idx, precision=pname, refresh=refresh,
                    guided=guided, slots=n_m,
                    energy_j=self._step_energy_j(pname, refresh,
                                                 guided) * n_m)
        # decode overlap: decodes dispatched LAST tick materialize now,
        # behind the UNet step(s) just launched above
        done: List[GenerationResult] = self._flush_pending(overlapped=True)
        if self.metrics.first_tick_s is None:
            # cold-start probe: time-to-first-served-tick, device work
            # included (one extra sync, paid once per metrics object)
            jax.block_until_ready(self.x)
            self.metrics.record_first_tick(
                time.perf_counter() - self._created)
        # x0-convergence deltas: materialized (one tiny device sync) only
        # when some active slot is actually early-exit eligible this tick
        deltas = np.zeros(self.slots, np.float32)
        if track_exit:
            for m, d in delta_parts:
                dn = np.asarray(d)
                deltas[m] = dn[m]
        for idx, a in enumerate(self._slot):
            if a is None:
                continue
            if needs_refresh[idx]:
                a.full_evals += 1
                a.force_refresh = False      # cache rows rewritten
            else:
                a.cached_evals += 1
            a.i += 1
            finished = early = False
            if a.i >= len(a.ts):
                finished = True
            elif a.exit_tol > 0.0 and a.i >= self.exit_min_steps:
                if deltas[idx] < a.exit_tol:
                    a.exit_streak += 1
                else:
                    a.exit_streak = 0
                if a.exit_streak >= a.exit_patience:
                    finished = early = True
            if finished:
                p = self._begin_drain(idx, now, wall_clock=wall_clock,
                                      early=early)
                if self.overlap_decode:
                    self._pending.append(p)   # sync behind the next tick
                else:
                    done.append(self._finish_drain(p))
        if caching and had_cached:
            self._phase = (self._phase + 1) % self.cache_interval
        if self.monitor is not None:
            # one process drives every simulated device, so each shard
            # records the same wall tick time — the hook a real
            # deployment feeds per-device timings into (check() then
            # recommends the elastic_resize target)
            dt = time.perf_counter() - t_tick0
            for dev in range(int(self.mesh.shape['data'])):
                self.monitor.record(dev, dt)
            self._poll_straggler()
        if traced:
            t1 = self.tracer.now()
            self.tracer.complete(
                'tick', t1 - (time.perf_counter() - t_tick0), t1,
                cat='tick', tick=tick_idx,
                active=int(active_mask.sum()), drained=len(done))
            self.tracer.counter('occupancy', cat='engine', tick=tick_idx,
                                active=self.active_count,
                                queued=len(self.queue))
        if self.reporter is not None:
            self.reporter.maybe_report(engine=self)
        return done

    def run_until_idle(self, now: Optional[float] = None,
                       max_ticks: int = 100_000,
                       tick_dt: float = 0.0) -> List[GenerationResult]:
        """Drive ticks until queue and slots are empty.  With a logical
        clock (`now` given), each tick advances it by `tick_dt`."""
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            if not self.busy:
                return results
            results.extend(self.tick(now))
            if now is not None:
                now += tick_dt
        raise RuntimeError(f'engine still busy after {max_ticks} ticks')

    def replay(self, requests: List[GenerationRequest],
               max_ticks: int = 1_000_000,
               on_result=None) -> List[GenerationResult]:
        """Wall-clock replay of an arrival trace: each request is
        submitted once the serving clock passes its ``arrival_time``;
        the engine idles (sleeps) when nothing has arrived yet.
        ``on_result`` is called with each result as it completes —
        the hook deployments use to trigger a mid-replay
        ``elastic_resize`` (any results it flushes should be collected
        by the caller; they do not pass through this return value)."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        t0 = self._wall_t0 = time.perf_counter()
        # trace clock := replay serving clock, so trace timestamps and
        # GenerationResult timing fields agree exactly
        self.tracer.set_origin(t0)
        results: List[GenerationResult] = []
        for _ in range(max_ticks):
            now = time.perf_counter() - t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0), now=now)
            if not self.busy:
                if not pending:
                    return results
                time.sleep(max(0.0, pending[0].arrival_time - now))
                continue
            # async dispatch overlaps host bookkeeping with device compute;
            # every drain materializes its image (device sync), so dispatch
            # can run ahead by at most one request's remaining steps
            batch = self.tick(now=time.perf_counter() - t0,
                              wall_clock=True)
            results.extend(batch)
            if on_result is not None:
                for res in batch:
                    on_result(res)
        raise RuntimeError('replay exceeded max_ticks')

    def elastic_resize(self, n_devices: Optional[int] = None,
                       devices=None, warm: bool = True,
                       precisions=('fp32',)) -> List[GenerationResult]:
        """Rebuild the slot buffer on a new ``('data',)`` mesh after
        devices drop or rejoin, preserving in-flight work.

        ``distributed.fault_tolerance.elastic_serving_plan`` sizes the
        new mesh and slot buffer at this engine's per-device slot budget
        (drop devices -> smaller buffer, never an overloaded survivor).
        In-flight latents and x0 trackers gather to the host and
        re-place onto the new buffer; when it is smaller, the overflow
        PARKS on the host and re-enters freed slots ahead of the queue.
        Every jitted function whose ``out_shardings`` pinned the old
        mesh is dropped and re-lowered for the new topology;
        ``warm=True`` pre-compiles the step variants via ``aot_warmup``
        (off the serving path — with a persistent compilation cache the
        re-lowering is a disk read).  Pending overlapped decodes flush
        first and their results are returned.  ``n_devices`` takes the
        first N visible devices; ``devices`` passes the surviving list
        explicitly."""
        if self.mesh is None:
            raise ValueError('elastic_resize needs a mesh-sharded engine '
                             '(construct with mesh=serving_mesh(...))')
        if n_devices is None and devices is None:
            raise ValueError('pass n_devices or an explicit device list')
        flushed = self._flush_pending(overlapped=False)
        from repro.launch.mesh import serving_mesh
        mesh = serving_mesh(n_devices=n_devices, devices=devices)
        old_ndev = int(self.mesh.shape['data'])
        new_ndev = int(mesh.shape['data'])
        _, _, new_slots = elastic_serving_plan(new_ndev,
                                               self._slots_per_device)
        # gather in-flight rows to the host before the old buffers die
        hx, hx0 = np.asarray(self.x), np.asarray(self.x0)
        live = [(a, hx[i], hx0[i]) for i, a in enumerate(self._slot)
                if a is not None]
        self.mesh = mesh
        self.slots = new_slots
        self._shard = named(mesh, PSpec('data'))
        self.x = self._zeros_buf((new_slots,) + self._sample_shape)
        self.x0 = self._zeros_buf((new_slots,) + self._sample_shape)
        if self._cache_row is not None:
            row_shape, row_dtype = self._cache_row
            self._cache_c = self._zeros_buf((new_slots,) + row_shape,
                                            row_dtype)
            if self._cache_u is not None:
                self._cache_u = self._zeros_buf((new_slots,) + row_shape,
                                                row_dtype)
        self._slot = [None] * new_slots
        # in-flight work ahead of previously-parked work ahead of queue
        self._parked = live + self._parked
        self._steps.clear()
        self._csteps.clear()
        self._build_helpers()
        self.monitor = StepMonitor(n_hosts=new_ndev)
        self._straggler_flagged = ()
        self.metrics.record_resize(old_ndev, new_ndev)
        self.tracer.instant('elastic_resize', cat='engine',
                            old_devices=old_ndev, new_devices=new_ndev,
                            slots=new_slots, parked=len(self._parked))
        for idx in range(self.slots):
            if not self._parked:
                break
            self._unpark(idx)
        if warm:
            self.aot_warmup(precisions=precisions)
        return flushed

    def warmup(self, precisions=('fp32',),
               cache_dir: Optional[str] = None) -> float:
        """Compile every code path (per-policy steps, place, take, decode
        — and, with caching on, the refresh AND skip variants) with
        throwaway requests so serving ticks never pay compile time.
        Pass every precision the engine will serve — e.g.
        ``warmup(('fp32', 'w8a8', 'w8a8+noise'))`` — one step compile per
        (policy, guided) pair (times the refresh/skip pair when caching),
        zero recompiles after.

        ``cache_dir`` routes every compilation through JAX's persistent
        on-disk cache first (``compile_cache.enable_persistent_cache``):
        the first (cold) warmup populates the directory, every later
        warmup in a fresh process loads executables from it instead of
        recompiling.  Returns wall seconds spent, also recorded in the
        metrics (``warmup_s``)."""
        if cache_dir is not None:
            from repro.serving.compile_cache import enable_persistent_cache
            enable_persistent_cache(cache_dir)
        t0 = time.perf_counter()
        saved_q, saved_m = self.queue, self.metrics
        saved_probe, saved_tracer = self.quality_probe, self.tracer
        self.queue, self.metrics = AdmissionQueue(), ServingMetrics()
        self.quality_probe = 0          # no fp32 references for throwaways
        self.tracer = NULL_TRACER       # throwaways must not pollute traces
        # enough steps to cross a refresh boundary: compiles refresh+skip
        steps = 1 if self.cache_interval <= 1 else self.cache_interval + 1
        try:
            for i, pname in enumerate(precisions):
                self.submit(GenerationRequest(request_id=-(2 * i + 1),
                                              seed=0, steps=steps,
                                              exit_tol=0.0,
                                              precision=pname), now=0.0)
                self.run_until_idle(now=0.0)
                if self.context is not None:
                    # separately: the guided tick variant
                    self.submit(GenerationRequest(request_id=-(2 * i + 2),
                                                  seed=0, steps=steps,
                                                  guidance=7.5,
                                                  exit_tol=0.0,
                                                  precision=pname), now=0.0)
                    self.run_until_idle(now=0.0)
        finally:
            self.queue, self.metrics = saved_q, saved_m
            self.quality_probe, self.tracer = saved_probe, saved_tracer
        dt = time.perf_counter() - t0
        self.metrics.record_warmup(dt)
        if self.tracer.enabled:
            t1 = self.tracer.now()
            self.tracer.complete('warmup', t1 - dt, t1, cat='engine',
                                 precisions=list(precisions), seconds=dt)
        trim_cache()    # enforce the persistent-cache size bound, if any
        return dt

    def step_variants(self, precisions=('fp32',)):
        """Every ``(precision, guided, refresh)`` step variant the given
        request mix can reach on this engine: guided variants exist only
        when the engine holds conditioning ``context``; refresh/skip
        variants only when DeepCache phasing is on (``refresh`` is None
        for the plain uncached step)."""
        guided_opts = (False, True) if self.context is not None else (False,)
        out = []
        for pname in precisions:
            for guided in guided_opts:
                if self.cache_interval > 1:
                    out.append((pname, guided, True))
                    out.append((pname, guided, False))
                else:
                    out.append((pname, guided, None))
        return out

    def aot_warmup(self, precisions=('fp32',),
                   cache_dir: Optional[str] = None) -> Dict[str, float]:
        """Ahead-of-time warmup: pre-lower and compile every step variant
        in ``step_variants(precisions)`` plus the fixed-shape helpers
        (init-noise, place, take, decode) WITHOUT executing a tick.

        With a persistent compilation cache enabled (``cache_dir`` or a
        prior ``enable_persistent_cache`` call) every executable lands on
        disk, so a restarted process — or this one's first served tick —
        finds a cache hit instead of paying XLA compilation.  Returns
        ``{'variants': count, 'seconds': wall}``."""
        if cache_dir is not None:
            from repro.serving.compile_cache import enable_persistent_cache
            enable_persistent_cache(cache_dir)
        t0 = time.perf_counter()
        S = jax.ShapeDtypeStruct
        # sharded engines lower against slot-sharded buffer shapes, so
        # the persisted executables are exactly the ones serving uses
        sh = {} if self._shard is None else {'sharding': self._shard}
        xs = S((self.slots,) + self._sample_shape, jnp.float32, **sh)
        ti = S((self.slots,), jnp.int32)
        act = S((self.slots,), jnp.bool_)
        gd = S((self.slots,), jnp.float32)
        key = S(self._zero_key.shape, self._zero_key.dtype)
        n = 0
        for pname, guided, refresh in self.step_variants(precisions):
            if refresh is None:
                fn = self._get_step(pname, guided)
                fn.lower(xs, xs, ti, ti, act, gd, key).compile()
            else:
                fn = self._get_cached_step(pname, guided, refresh)
                cs = S(self._cache_c.shape, self._cache_c.dtype, **sh)
                if guided:
                    fn.lower(xs, xs, cs, cs, ti, ti, act, gd,
                             key).compile()
                else:
                    fn.lower(xs, xs, cs, ti, ti, act, gd, key).compile()
            n += 1
        idx = S((), jnp.int32)
        sample = S(self._sample_shape, jnp.float32)
        self._init_noise.lower(key).compile()
        self._place.lower(xs, idx, sample).compile()
        self._take.lower(xs, idx).compile()
        n += 3
        if self._decode is not None:
            self._decode.lower(S((1,) + self._sample_shape,
                                 jnp.float32)).compile()
            n += 1
        trim_cache()    # enforce the persistent-cache size bound, if any
        dt = time.perf_counter() - t0
        if self.tracer.enabled:
            t1 = self.tracer.now()
            self.tracer.complete('aot_warmup', t1 - dt, t1, cat='engine',
                                 variants=n, seconds=dt)
        return {'variants': n, 'seconds': dt}

    def measure_tick_s(self, steps: int = 4) -> float:
        """Steady-state wall seconds per engine tick at full slot
        occupancy (throwaway requests, metrics untouched) — the service
        capacity anchor for overload sizing: the engine completes
        ``slots / (steps * tick_s)`` requests/s.  Call after warmup so
        no compile time leaks into the measurement."""
        saved_q, saved_m = self.queue, self.metrics
        saved_probe, saved_tracer = self.quality_probe, self.tracer
        self.queue, self.metrics = AdmissionQueue(), ServingMetrics()
        self.quality_probe = 0
        self.tracer = NULL_TRACER       # throwaways must not pollute traces
        try:
            for i in range(self.slots):
                self.submit(GenerationRequest(request_id=-(100 + i),
                                              seed=i, steps=steps,
                                              exit_tol=0.0), now=0.0)
            t0 = time.perf_counter()
            self.run_until_idle(now=0.0)
            dt = time.perf_counter() - t0
            ticks = max(self.metrics.ticks, 1)
        finally:
            self.queue, self.metrics = saved_q, saved_m
            self.quality_probe, self.tracer = saved_probe, saved_tracer
        self._tick_s = dt / ticks    # feeds the admission SLO margin
        return self._tick_s
