"""mamba2-2.7b [arXiv:2405.21060; unverified].  64L d_model=2560,
attention-free SSD, d_state=128, headdim=64 (-> 80 heads), expand=2,
n_groups=1 (HF state-spaces/mamba2-2.7b), vocab=50280 (padded 50432)."""
from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import pad_vocab

CONFIG = ArchConfig(
    name='mamba2-2.7b',
    family='ssm',
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=pad_vocab(50280, 256),       # 50280 -> 50432
    norm='rmsnorm',
    rope='none',
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1,
                  d_conv=4, chunk=256),
    tie_embeddings=True,
)
REAL_VOCAB = 50280
