"""qwen2-vl-7b [arXiv:2409.12191].  28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, M-RoPE (sections 16/24/24); vision frontend is a
STUB (input_specs provides patch embeddings)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='qwen2-vl-7b',
    family='vlm',
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    act='swish',
    norm='rmsnorm',
    rope='mrope',
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    attn_bias=True,
    frontend='vision_stub',
    kv_repeat=1,     # 28 q-heads: kv shards 4-way
)
REAL_VOCAB = 152064
