"""The paper's own evaluated models (Table I), with UNet hyper-parameters
calibrated so parameter counts match the published numbers to <0.5%
(asserted in tests/test_diffusion.py):

  DDPM  / CIFAR-10        61.9M   -> 61.66M
  LDM 1 / LSUN-Churches  294.96M  -> 295.40M
  LDM 2 / LSUN-Beds      274.05M  -> 275.21M
  SD v1-4                859.52M  -> 861.97M
"""
from __future__ import annotations

from repro.models.autoencoder import VAEConfig
from repro.models.unet import UNetConfig

DDPM_CIFAR10 = UNetConfig(
    name='ddpm_cifar10', img_size=32, in_ch=3, base_ch=165,
    ch_mults=(1, 2, 2, 2), n_res_blocks=2, attn_resolutions=(16,),
    n_heads=8, timesteps=1000)

LDM_CHURCHES = UNetConfig(
    name='ldm_churches', img_size=32, in_ch=4, base_ch=207,
    ch_mults=(1, 2, 2, 4, 4), n_res_blocks=2, attn_resolutions=(16, 8),
    n_heads=8, timesteps=1000, latent=True)

LDM_BEDS = UNetConfig(
    name='ldm_beds', img_size=64, in_ch=3, base_ch=222,
    ch_mults=(1, 2, 3, 4), n_res_blocks=2, attn_resolutions=(16, 8),
    n_heads=8, timesteps=1000, latent=True)

SD_V1_4 = UNetConfig(
    name='sd_v1_4', img_size=64, in_ch=4, base_ch=340,
    ch_mults=(1, 2, 4, 4), n_res_blocks=2, attn_resolutions=(32, 16, 8),
    n_heads=8, context_dim=768, timesteps=1000, latent=True)

VAE_256 = VAEConfig(img_size=256, in_ch=3, z_ch=4, base_ch=128,
                    ch_mults=(1, 2, 4, 4))
VAE_512 = VAEConfig(img_size=512, in_ch=3, z_ch=4, base_ch=128,
                    ch_mults=(1, 2, 4, 4))

PAPER_MODELS = {
    'ddpm_cifar10': DDPM_CIFAR10,
    'ldm_churches': LDM_CHURCHES,
    'ldm_beds': LDM_BEDS,
    'sd_v1_4': SD_V1_4,
}

PAPER_PARAM_COUNTS = {          # Table I, millions
    'ddpm_cifar10': 61.9,
    'ldm_churches': 294.96,
    'ldm_beds': 274.05,
    'sd_v1_4': 859.52,
}

# Table I: IS reduction after 8-bit quantization (%)
PAPER_IS_REDUCTION = {
    'ddpm_cifar10': 0.44,
    'ldm_churches': 0.43,
    'ldm_beds': 5.26,
    'sd_v1_4': 6.66,
}
