"""jamba-1.5-large-398b [arXiv:2403.19887].  72L d_model=8192 64H (GQA kv=8)
d_ff=24576, Mamba+attention 1:7 interleave (attention at position 4 of each
8-layer super-block), MoE 16 experts top-2 on every other FFN."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name='jamba-1.5-large-398b',
    family='hybrid',
    n_layers=72,                # 9 scanned super-blocks of 8
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    act='swish',
    norm='rmsnorm',
    rope='rope',
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=8,
                  d_conv=4, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    hybrid_block=('M', 'M', 'M', 'A', 'M', 'M', 'M', 'M'),
    hybrid_ffn=('D', 'E', 'D', 'E', 'D', 'E', 'D', 'E'),
    kv_repeat=2,
    # >100B deployment defaults (EXPERIMENTS.md §Perf iterations 3/fixes):
    # dots-remat cuts the collective+memory terms ~3.6x vs full remat
    remat='dots',
)
REAL_VOCAB = 65536
