"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155 (padded to 49408 for 16-way TP),
MoE 32 experts top-8, d_ff=512 per expert.
"""
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import pad_vocab

CONFIG = ArchConfig(
    name='granite-moe-1b-a400m',
    family='moe',
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=pad_vocab(49155, 256),      # 49155 -> 49408
    act='swish',
    norm='rmsnorm',
    rope='rope',
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    kv_repeat=2,                       # kv 8 -> 16 for even 16-way TP
    tie_embeddings=True,
)
REAL_VOCAB = 49155
