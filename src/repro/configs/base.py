"""Architecture / run configuration schema.

One ``ArchConfig`` describes a full model; ``ShapeConfig`` describes one
assigned input-shape cell.  Configs are plain frozen dataclasses so they hash
(static args under jit) and serialize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    router_normalize: bool = True   # renormalize top-k probs
    every: int = 1              # MoE FFN every `every` layers (else dense)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 8
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = 'swish'
    norm: str = 'rmsnorm'                   # rmsnorm | layernorm
    rope: str = 'rope'                      # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): layer kinds within one scanned super-block.
    # 'A' = attention, 'M' = mamba; ffn kinds: 'D' dense, 'E' moe.
    hybrid_block: Tuple[str, ...] = ()
    hybrid_ffn: Tuple[str, ...] = ()
    n_enc_layers: int = 0                   # encdec only
    frontend: str = 'none'                  # none | audio_stub | vision_stub
    max_seq_len: int = 1 << 20
    # distribution hints
    # model_axis_tp=False: keep the 'model' mesh axis for EXPERT parallelism
    # only — attention / dense-MLP weights shard over 'data' (FSDP) and
    # activations are never tensor-parallel.  Wins for small-d_model MoE
    # archs where TP all-reduces dwarf the tiny per-shard matmuls (§Perf).
    model_axis_tp: bool = True
    kv_repeat: int = 1                      # replicate KV heads for even TP
    moe_groups: int = 32                    # dispatch groups (>= data shards)
    remat: str = 'full'                     # full | dots | none
    # unrolled layer loop (no lax.scan while-loop): used by the dry-run cost
    # probes because XLA cost analysis counts a while body once, ignoring
    # trip count; production path keeps scan for O(1) HLO size.
    unroll_layers: bool = False
    # quantization (paper C1): serve path W8A8
    w8a8_serve: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def full_attention(self) -> bool:
        """True if *all* sequence mixing is quadratic attention (these archs
        skip the long_500k cell)."""
        return self.family in ('dense', 'moe', 'encdec', 'vlm') and \
            self.ssm is None

    def scaled(self, **kw) -> 'ArchConfig':
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == 'decode'


SHAPES = {
    'train_4k': ShapeConfig('train_4k', 4096, 256, 'train'),
    'prefill_32k': ShapeConfig('prefill_32k', 32768, 32, 'prefill'),
    'decode_32k': ShapeConfig('decode_32k', 32768, 128, 'decode'),
    'long_500k': ShapeConfig('long_500k', 524288, 1, 'decode'),
}


def shape_cells(arch: ArchConfig):
    """The live (shape) cells for an arch (full-attention archs skip
    long_500k — see DESIGN.md §4)."""
    names = ['train_4k', 'prefill_32k', 'decode_32k']
    if not arch.full_attention:
        names.append('long_500k')
    return [SHAPES[n] for n in names]
