"""starcoder2-7b [arXiv:2402.19173].  32L d_model=4608 36H (GQA kv=4)
d_ff=18432 vocab=49152, GQA + RoPE, gelu, layernorm, biases."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='starcoder2-7b',
    family='dense',
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    act='gelu',
    norm='layernorm',
    rope='rope',
    rope_theta=1e5,
    attn_bias=True,
    mlp_bias=True,
    kv_repeat=1,     # 36 q-heads: no even kv replication; cache heads
                     # shard 4-way (DESIGN.md §4)
)
REAL_VOCAB = 49152
