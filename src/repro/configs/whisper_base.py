"""whisper-base [arXiv:2212.04356; unverified].  6L enc + 6L dec,
d_model=512 8H d_ff=2048 vocab=51865 (padded 51968); conv/audio frontend is
a STUB per the assignment (input_specs provides frame embeddings)."""
from repro.configs.base import ArchConfig
from repro.models.layers import pad_vocab

CONFIG = ArchConfig(
    name='whisper-base',
    family='encdec',
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=pad_vocab(51865, 256),       # 51865 -> 51968
    act='gelu',
    norm='layernorm',
    rope='none',
    attn_bias=True,
    mlp_bias=True,
    frontend='audio_stub',
    kv_repeat=1,
)
REAL_VOCAB = 51865
