"""yi-34b [arXiv:2403.04652].  60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000, llama-arch."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='yi-34b',
    family='dense',
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    act='swish',
    norm='rmsnorm',
    rope='rope',
    kv_repeat=1,     # 56 q-heads not divisible by 16 kv_eff; kv shards 8-way
)
REAL_VOCAB = 64000
