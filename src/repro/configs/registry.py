"""Architecture registry: ``--arch <id>`` resolution + smoke-scale
reduction for CPU tests."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs import (deepseek_v2_lite_16b, granite_moe_1b_a400m,
                           internlm2_1_8b, jamba_1_5_large_398b,
                           mamba2_2_7b, mistral_large_123b, qwen2_vl_7b,
                           starcoder2_7b, whisper_base, yi_34b)
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

_MODULES = {
    'granite-moe-1b-a400m': granite_moe_1b_a400m,
    'deepseek-v2-lite-16b': deepseek_v2_lite_16b,
    'starcoder2-7b': starcoder2_7b,
    'internlm2-1.8b': internlm2_1_8b,
    'mistral-large-123b': mistral_large_123b,
    'yi-34b': yi_34b,
    'mamba2-2.7b': mamba2_2_7b,
    'whisper-base': whisper_base,
    'jamba-1.5-large-398b': jamba_1_5_large_398b,
    'qwen2-vl-7b': qwen2_vl_7b,
}

ARCHS: Dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
REAL_VOCABS: Dict[str, int] = {k: m.REAL_VOCAB for k, m in _MODULES.items()}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f'unknown arch {name!r}; known: {sorted(ARCHS)}')
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab — runs a forward/train step on CPU in seconds."""
    cfg = get(name)
    kw = dict(
        name=cfg.name + '-smoke',
        n_layers=max(2, len(cfg.hybrid_block) or 2),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=211,
        kv_repeat=1,
        moe_groups=2,
        remat='none',
        max_seq_len=256,
    )
    if cfg.moe is not None:
        kw['moe'] = MoEConfig(n_experts=4, top_k=min(cfg.moe.top_k, 2),
                              d_ff_expert=32, n_shared=cfg.moe.n_shared)
    if cfg.mla is not None:
        kw['mla'] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm is not None:
        kw['ssm'] = SSMConfig(d_state=16, headdim=8, expand=2,
                              n_groups=1 if cfg.ssm.n_groups == 1 else 2,
                              d_conv=4, chunk=16)
    if cfg.family == 'encdec':
        kw['n_enc_layers'] = 2
    if cfg.family == 'hybrid':
        kw['n_layers'] = len(cfg.hybrid_block)   # one super-block
    if cfg.rope == 'mrope':
        kw['mrope_sections'] = (2, 3, 3)
    return dataclasses.replace(cfg, **kw)
