"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified].
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='mistral-large-123b',
    family='dense',
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    act='swish',
    norm='rmsnorm',
    rope='rope',
    kv_repeat=2,
    # >100B deployment defaults (EXPERIMENTS.md §Perf iterations 3/fixes):
    # dots-remat cuts the collective+memory terms ~3.6x vs full remat
    remat='dots',
)
REAL_VOCAB = 32768
