"""deepseek-v2-lite-16b [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (qk nope 128 / rope 64 / v 128),
MoE 64 routed experts top-6 + 2 shared, d_ff=1408 per expert,
vocab=102400.  (The assignment brief lists both "64e" and "160 routed";
DeepSeek-V2-**Lite** has 64 routed experts — we follow the primary spec.
The real model's first dense layer is folded into the uniform MoE stack for
scan-ability; noted in DESIGN.md.)
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name='deepseek-v2-lite-16b',
    family='moe',
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    act='swish',
    norm='rmsnorm',
    rope='rope',
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
)
REAL_VOCAB = 102400
