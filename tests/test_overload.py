"""Overload hardening tests: the bounded admission queue sheds instead
of growing, deadline-aware shedding evicts the least-slack entry (and
only then), expired requests are dropped at admission rather than ever
occupying a denoising slot, and the engine's shed counters reconcile
exactly with what a deterministic burst offered."""
import math

import jax
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                           GenerationRequest, offered_load,
                           overload_factor)

TINY = UNetConfig('tiny-overload', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


def _req(i, **kw):
    kw.setdefault('steps', 2)
    return GenerationRequest(request_id=i, seed=100 + i, **kw)


# ---------------------------------------------------------------------------
# queue bound + shed accounting
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.overload
def test_bounded_queue_sheds_instead_of_growing():
    q = AdmissionQueue(max_depth=3)
    admitted = [q.submit(_req(i), now=float(i)) for i in range(5)]
    assert admitted == [True, True, True, False, False]
    assert len(q) == 3
    assert q.rejected == 2 and q.shed == 2
    assert q.submitted == 3
    # the three that fit come out in FIFO order
    assert [q.pop().request.request_id for _ in range(3)] == [0, 1, 2]


@pytest.mark.overload
def test_unbounded_queue_never_sheds():
    q = AdmissionQueue()
    for i in range(50):
        assert q.submit(_req(i), now=0.0)
    assert len(q) == 50 and q.shed == 0


@pytest.mark.overload
def test_unknown_shed_policy_rejected():
    with pytest.raises(ValueError, match='shed_policy'):
        AdmissionQueue(max_depth=2, shed_policy='drop-everything')


@pytest.mark.overload
def test_nonpositive_slo_rejected_at_request():
    with pytest.raises(ValueError, match='slo_ms'):
        _req(0, slo_ms=0.0)


# ---------------------------------------------------------------------------
# deadline-aware shedding
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.overload
def test_deadline_aware_evicts_least_slack_entry():
    q = AdmissionQueue(max_depth=2, shed_policy='deadline-aware')
    assert q.submit(_req(0, slo_ms=100.0), now=0.0)    # deadline 0.1
    assert q.submit(_req(1, slo_ms=5000.0), now=0.0)   # deadline 5.0
    # an arrival with more slack than the tightest entry displaces it
    assert q.submit(_req(2, slo_ms=1000.0), now=0.0)   # deadline 1.0
    assert q.evicted == 1 and len(q) == 2
    ids = {q.pop().request.request_id, q.pop().request.request_id}
    assert ids == {1, 2}                               # 0 was shed


@pytest.mark.overload
def test_deadline_aware_rejects_arrival_with_least_slack():
    q = AdmissionQueue(max_depth=2, shed_policy='deadline-aware')
    assert q.submit(_req(0, slo_ms=1000.0), now=0.0)
    assert q.submit(_req(1, slo_ms=2000.0), now=0.0)
    # tighter than everything queued: the arrival itself is shed
    assert not q.submit(_req(2, slo_ms=10.0), now=0.0)
    assert q.rejected == 1 and q.evicted == 0 and len(q) == 2


@pytest.mark.overload
def test_deadline_aware_never_evicts_slo_free_entries():
    """No-SLO entries have an infinite deadline: an SLO-carrying arrival
    can never displace them (eviction needs strictly more slack)."""
    q = AdmissionQueue(max_depth=2, shed_policy='deadline-aware')
    assert q.submit(_req(0), now=0.0)
    assert q.submit(_req(1), now=0.0)
    assert not q.submit(_req(2, slo_ms=60_000.0), now=0.0)
    assert q.rejected == 1 and q.evicted == 0
    assert all(q.pop().deadline == math.inf for _ in range(2))


@pytest.mark.overload
def test_expire_drops_dead_entries():
    q = AdmissionQueue(shed_policy='deadline-aware')
    q.submit(_req(0, slo_ms=100.0), now=0.0)           # deadline 0.1
    q.submit(_req(1), now=0.0)                         # no SLO: immortal
    assert q.expire(now=0.05) == []                    # still has slack
    dead = q.expire(now=0.2)
    assert [d.request.request_id for d in dead] == [0]
    assert q.expired == 1 and len(q) == 1
    # margin folds estimated service time into the cutoff: a request that
    # WILL miss by completion is shed at admission too
    q.submit(_req(2, slo_ms=100.0), now=1.0)           # deadline 1.1
    assert [d.request.request_id
            for d in q.expire(now=1.05, margin_s=0.1)] == [2]


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.overload
def test_engine_shed_counters_match_deterministic_burst(pipe):
    """6 requests burst into depth-3 queue: exactly 3 admitted, 3 shed
    as queue_full, and completed + shed reconciles with the offer."""
    engine = ContinuousBatchingEngine(
        pipe, slots=2, quality_probe=0,
        queue=AdmissionQueue(max_depth=3))
    engine.warmup()
    admitted = [engine.submit(_req(i), now=0.0) for i in range(6)]
    assert admitted.count(True) == 3
    results = engine.run_until_idle(now=0.0, tick_dt=0.01)
    s = engine.metrics.summary()
    assert len(results) == 3
    assert s['shed'] == 3.0
    assert engine.metrics.shed_by_reason == {'queue_full': 3}
    assert len(results) + int(s['shed']) == 6
    assert s['max_queue_depth'] <= 3


@pytest.mark.overload
def test_expired_request_never_occupies_slot(pipe):
    """A request whose deadline passes while queued is shed at admission
    (reason 'expired') — it never reaches a slot, never produces a
    result, and the engine still drains cleanly."""
    engine = ContinuousBatchingEngine(
        pipe, slots=1, quality_probe=0,
        queue=AdmissionQueue(shed_policy='deadline-aware'))
    engine.warmup()
    assert engine.submit(_req(0, steps=3), now=0.0)            # heads a slot
    assert engine.submit(_req(1, steps=3, slo_ms=1.0), now=0.0)  # dies queued
    results = engine.run_until_idle(now=1.0, tick_dt=0.01)
    assert [r.request_id for r in results] == [0]
    assert engine.metrics.shed_by_reason == {'expired': 1}
    assert engine.metrics.summary()['deadline_sheds'] == 1.0


@pytest.mark.overload
def test_queue_wait_percentiles_and_depth(pipe):
    """Queue-wait percentiles come from completed requests' queue delay:
    ordered, non-negative, and the peak depth reflects the burst."""
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    engine.warmup()
    for i in range(5):
        assert engine.submit(_req(i), now=0.0)
    results = engine.run_until_idle(now=0.0, tick_dt=0.01)
    assert len(results) == 5
    s = engine.metrics.summary()
    assert 0.0 <= s['p50_queue_wait_ms'] <= s['p99_queue_wait_ms']
    assert s['max_queue_depth'] >= 3          # 5 arrivals, 2 slots


# ---------------------------------------------------------------------------
# load model
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.overload
def test_overload_factor_little_law():
    # 10 req/s x 10 steps x 50 ms = 5 in flight; 1 slot -> 5x overload
    assert offered_load(10.0, 0.05, 10) == pytest.approx(5.0)
    assert overload_factor(10.0, 0.05, 10, slots=1) == pytest.approx(5.0)
    assert overload_factor(10.0, 0.05, 10, slots=5) == pytest.approx(1.0)
    # per-precision mappings add (shared slot buffer)
    load = offered_load({'fp32': 1.0, 'w8a8': 4.0},
                        {'fp32': 0.1, 'w8a8': 0.025}, 10)
    assert load == pytest.approx(1.0 * 10 * 0.1 + 4.0 * 10 * 0.025)
    with pytest.raises(ValueError):
        overload_factor(1.0, 0.1, 10, slots=0)


# ---------------------------------------------------------------------------
# SLO expiry fixes: expiry under EVERY policy, service-time-aware margin
# ---------------------------------------------------------------------------

@pytest.mark.overload
def test_has_deadlines_property():
    q = AdmissionQueue()
    assert not q.has_deadlines
    q.submit(_req(0), now=0.0)                         # no SLO
    assert not q.has_deadlines
    q.submit(_req(1, slo_ms=100.0), now=0.0)
    assert q.has_deadlines
    q.expire(now=1.0)
    assert not q.has_deadlines                         # only immortals left


@pytest.mark.overload
def test_expire_accepts_per_request_margin():
    """margin_s may be a callable of the request — the engine passes its
    estimated service time (steps x tick_s), so longer requests get a
    larger will-miss margin."""
    q = AdmissionQueue()
    q.submit(_req(0, steps=2, slo_ms=1000.0), now=0.0)   # deadline 1.0
    q.submit(_req(1, steps=50, slo_ms=1000.0), now=0.0)  # deadline 1.0
    dead = q.expire(now=0.5, margin_s=lambda r: r.steps * 0.02)
    # 50-step request needs 1.0s of service: 0.5 + 1.0 > deadline -> dead;
    # the 2-step one (0.04s) still fits
    assert [d.request.request_id for d in dead] == [1]
    assert len(q) == 1


@pytest.mark.overload
def test_expiry_runs_under_reject_newest_policy(pipe):
    """Regression: expiry used to run only when shed_policy was
    'deadline-aware'.  The SLO is a property of the REQUEST — under the
    default reject-newest policy (or an unbounded queue) a dead request
    must still be shed at admission, never served."""
    engine = ContinuousBatchingEngine(
        pipe, slots=1, quality_probe=0,
        queue=AdmissionQueue())                # default policy, unbounded
    engine.warmup()
    assert engine.submit(_req(0, steps=3), now=0.0)              # slot
    assert engine.submit(_req(1, steps=3, slo_ms=1.0), now=0.0)  # queued
    results = engine.run_until_idle(now=1.0, tick_dt=0.01)
    assert [r.request_id for r in results] == [0]
    assert engine.metrics.shed_by_reason == {'expired': 1}


@pytest.mark.overload
def test_admission_sheds_requests_that_will_miss_slo(pipe):
    """A queued request whose deadline has NOT passed yet, but which
    cannot finish inside it given the measured tick time, is shed at
    admission instead of burning slot time on a guaranteed miss."""
    engine = ContinuousBatchingEngine(
        pipe, slots=1, quality_probe=0,
        queue=AdmissionQueue(shed_policy='deadline-aware'))
    engine.warmup()
    assert engine.tick_s_estimate is None      # nothing measured yet
    engine.tick_s_estimate = 10.0              # pinned: 10 s per tick
    assert engine.submit(_req(0, steps=3), now=0.0)
    # 5 s of slack left at admission, but 3 steps x 10 s/tick can't fit
    assert engine.submit(_req(1, steps=3, slo_ms=5000.0), now=0.0)
    results = engine.run_until_idle(now=0.0, tick_dt=0.01)
    assert [r.request_id for r in results] == [0]
    assert engine.metrics.shed_by_reason == {'expired': 1}
    # with no estimate the same request would have been served
    engine2 = ContinuousBatchingEngine(
        pipe, slots=1, quality_probe=0,
        queue=AdmissionQueue(shed_policy='deadline-aware'))
    engine2.warmup()
    assert engine2.submit(_req(0, steps=3), now=0.0)
    assert engine2.submit(_req(1, steps=3, slo_ms=5000.0), now=0.0)
    assert len(engine2.run_until_idle(now=0.0, tick_dt=0.01)) == 2


@pytest.mark.overload
def test_measure_tick_s_feeds_estimate(pipe):
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    engine.warmup()
    t = engine.measure_tick_s(steps=2)
    assert t > 0.0
    assert engine.tick_s_estimate == t
