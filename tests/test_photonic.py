"""DiffLight simulator tests: Table II constants, loss budget, workload
extraction, and the paper's headline claims (Fig. 8 ablation, Figs. 9-10
ratios, DSE)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.diffusion import PAPER_MODELS
from repro.core.photonic import devices as dev
from repro.core.photonic.arch import (BASELINE, PAPER_OPTIMUM,
                                      DiffLightConfig, dse_space)
from repro.core.photonic.baselines import (EPB_IMPROVEMENT,
                                           GOPS_IMPROVEMENT,
                                           derive_baselines)
from repro.core.photonic.simulator import ablation, dse_score, simulate
from repro.core.photonic.workload import unet_workload


def _workloads():
    return {n: unet_workload(c, ctx_len=77 if c.context_dim else None)
            for n, c in PAPER_MODELS.items()}


def test_table2_constants():
    assert dev.EO_TUNING.latency == 20e-9
    assert dev.ADC_8B.latency == pytest.approx(0.82e-9)
    assert dev.DAC_8B.power == pytest.approx(3e-3)
    assert dev.LUT.power == pytest.approx(4.21e-3)
    assert len(dev.TABLE_II) == 10


def test_wdm_limit_enforced():
    with pytest.raises(AssertionError):
        dev.path_loss_db(40)
    cfg = DiffLightConfig(N=48)
    with pytest.raises(AssertionError):
        cfg.validate()


def test_laser_power_factor_positive():
    f = dev.laser_power_factor(36)
    assert 1.0 < f < 10.0     # a few dB of loss


def test_workload_positive_and_convt_share():
    for name, w in _workloads().items():
        assert w.total_macs_dense > 0
        assert 0.05 < w.convt_macs / w.total_macs_dense < 0.5, name
        assert w.softmax_elems > 0
        # sparse dataflow strictly reduces MACs
        assert w.total_macs(True) < w.total_macs(False)


def test_workload_matches_analytic_unet():
    """Cross-check the walker against a hand-computed tiny UNet."""
    from repro.models.unet import UNetConfig
    cfg = UNetConfig('t', img_size=8, in_ch=1, base_ch=8, ch_mults=(1,),
                     n_res_blocks=1, attn_resolutions=(), n_heads=1)
    w = unet_workload(cfg, ctx_len=None)
    # conv_in 9*1*8*64 + res (9*8*8*64)*2 + mid 2 res (9*8*8*64)*2
    # + up res (9*16*8*64 + skips...) -- just assert the closed form pieces
    assert w.conv_macs > 9 * 1 * 8 * 64
    assert w.convt_macs == 0          # single level -> no upsample


def test_fig8_ablation_3x():
    """Headline: combined optimizations ~3x energy vs baseline (Fig. 8)."""
    ratios = []
    for name, w in _workloads().items():
        ab = ablation(w)
        r = ab['baseline'].energy_j / ab['combined'].energy_j
        ratios.append(r)
        # each individual optimization helps
        for k in ('sw_opt', 'pipelined', 'dac_sharing'):
            assert ab[k].energy_j < ab['baseline'].energy_j, (name, k)
    avg = float(np.mean(ratios))
    assert avg >= 3.0, ratios          # paper: "3x reduction on average"
    assert avg < 5.0                   # sanity: same order as the paper


def test_fig9_fig10_claimed_ratios():
    """DiffLight >= 5.5x GOPS and >= 3x lower EPB vs best baseline."""
    ws = _workloads()
    reps = [simulate(w, PAPER_OPTIMUM) for w in ws.values()]
    gops = float(np.mean([r.gops for r in reps]))
    epb = float(np.mean([r.epb_pj for r in reps]))
    base = derive_baselines(gops, epb)
    best_gops = max(b.gops for b in base.values())
    best_epb = min(b.epb_pj for b in base.values())
    assert gops / best_gops >= 5.5 * 0.999
    assert best_epb / epb >= 3.0 * 0.999


def test_pipelining_improves_throughput():
    w = list(_workloads().values())[0]
    pip = simulate(w, dataclasses.replace(BASELINE, pipelined=True))
    assert pip.gops > simulate(w, BASELINE).gops


def test_sparse_dataflow_improves_gops_not_ops():
    w = list(_workloads().values())[0]
    a = simulate(w, BASELINE)
    b = simulate(w, dataclasses.replace(BASELINE, sparse_dataflow=True))
    assert b.latency_s < a.latency_s
    assert a.ops == b.ops             # nominal ops unchanged (zero-skipping)


def test_dse_paper_config_valid_and_competitive():
    """Paper's [4,12,3,6,6,3] is WDM-valid and lands in the top half of the
    budget-constrained space under our calibrated cost model (EXPERIMENTS.md
    reports the exact percentile)."""
    PAPER_OPTIMUM.validate()
    w = unet_workload(PAPER_MODELS['sd_v1_4'], ctx_len=77)

    def mr_count(c):
        return (c.Y * 2 * c.K * c.N + c.H * (4 * c.M * c.L + 3 * c.M * c.N)
                + 2 * c.M * c.L)
    budget = 1.1 * mr_count(PAPER_OPTIMUM)
    scores = sorted((dse_score(w, c) for c in dse_space()
                     if mr_count(c) <= budget), reverse=True)
    mine = dse_score(w, PAPER_OPTIMUM)
    pct = np.searchsorted(-np.asarray(scores), -mine) / len(scores)
    assert pct < 0.6, pct
