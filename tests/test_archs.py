"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import shape_cells
from repro.configs.registry import ARCHS, get, smoke_config
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step, init_params,
                                init_serve_state, make_batch_struct)
from repro.optim.adamw import AdamWConfig, init_adamw

ALL = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    b = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         'labels': jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == 'encdec':
        b['frames'] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32)
    return b


@pytest.mark.parametrize('name', ALL)
def test_full_config_exact(name):
    """The full (production) config matches the assignment spec."""
    cfg = get(name)
    spec = {
        'granite-moe-1b-a400m': (24, 1024, 16, 8),
        'deepseek-v2-lite-16b': (27, 2048, 16, 16),
        'starcoder2-7b': (32, 4608, 36, 4),
        'internlm2-1.8b': (24, 2048, 16, 8),
        'mistral-large-123b': (88, 12288, 96, 8),
        'yi-34b': (60, 7168, 56, 8),
        'mamba2-2.7b': (64, 2560, 0, 0),
        'whisper-base': (6, 512, 8, 8),
        'jamba-1.5-large-398b': (72, 8192, 64, 8),
        'qwen2-vl-7b': (28, 3584, 28, 4),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads) == spec


@pytest.mark.parametrize('name', ALL)
def test_smoke_train_step(name):
    cfg = smoke_config(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(warmup_steps=2, total_steps=10),
        dtype=jnp.float32))
    batch = _batch(cfg)
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics['loss']))
    assert np.isfinite(float(metrics['grad_norm']))
    # params actually changed
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize('name', ALL)
def test_smoke_serve_prefill_decode(name):
    cfg = smoke_config(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    state = init_serve_state(cfg, B, S + 4, cache_dtype=jnp.float32)
    batch = _batch(cfg, B, S)
    batch.pop('labels')
    prefill = jax.jit(build_prefill_step(cfg, dtype=jnp.float32))
    decode = jax.jit(build_decode_step(cfg, dtype=jnp.float32))
    tok, state = prefill(params, state, batch)
    assert tok.shape == (B, 1) and tok.dtype == jnp.int32
    tok, state = decode(params, state, tok, jnp.int32(S))
    assert tok.shape == (B, 1)
    assert int(tok.min()) >= 0 and int(tok.max()) < cfg.vocab


@pytest.mark.parametrize('name', ALL)
def test_loss_decreases(name):
    """A few steps on a learnable synthetic stream must reduce loss."""
    cfg = smoke_config(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=30),
        dtype=jnp.float32))
    rng = np.random.default_rng(3)
    # fixed batch -> loss must drop when overfitting
    batch = _batch(cfg, B=2, S=16)
    first = last = None
    for i in range(8):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m['loss'])
        last = float(m['loss'])
    assert last < first, (first, last)


def test_shape_cells_skips():
    """long_500k lives only for non-full-attention archs (DESIGN.md §4)."""
    live = {n: [s.name for s in shape_cells(get(n))] for n in ALL}
    for n in ('mamba2-2.7b', 'jamba-1.5-large-398b'):
        assert 'long_500k' in live[n]
    for n in set(ALL) - {'mamba2-2.7b', 'jamba-1.5-large-398b'}:
        assert 'long_500k' not in live[n]
    total = sum(len(v) for v in live.values())
    assert total == 32   # 10*3 + 2


@pytest.mark.parametrize('name', ALL)
def test_vocab_padding_divisible(name):
    cfg = get(name)
    assert cfg.vocab % 256 == 0 or cfg.vocab % 16 == 0
