"""Cold-start hardening tests: persistent compilation cache wiring,
warmup/first-tick accounting, AOT pre-lowering of every step variant,
and a real process-restart check (cold populates the cache, warm loads
from it and is faster)."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           active_cache_dir, cache_entries,
                           disable_persistent_cache,
                           enable_persistent_cache)

TINY = UNetConfig('tiny-cold', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


# ---------------------------------------------------------------------------
# compile_cache wiring
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_enable_persistent_cache_configures_jax():
    with tempfile.TemporaryDirectory() as d:
        target = os.path.join(d, 'xla-cache')
        try:
            path = enable_persistent_cache(target)
            assert os.path.isdir(path)
            assert active_cache_dir() == path
            assert jax.config.jax_compilation_cache_dir == path
            assert cache_entries() == 0          # enabled, nothing stored
        finally:
            disable_persistent_cache()
        assert active_cache_dir() is None
        assert jax.config.jax_compilation_cache_dir is None


@pytest.mark.coldstart
def test_cache_entries_handles_missing_and_inactive():
    assert cache_entries('/nonexistent/no-such-cache-dir') == 0
    assert active_cache_dir() is None
    assert cache_entries() == 0                  # nothing active


# ---------------------------------------------------------------------------
# warmup / first-tick accounting
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_warmup_and_first_tick_recorded(pipe):
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    dt = engine.warmup()
    assert dt > 0.0
    assert engine.metrics.warmup_s == pytest.approx(dt)
    assert engine.metrics.first_tick_s is None   # nothing served yet
    engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
    engine.run_until_idle(now=0.0)
    first = engine.metrics.first_tick_s
    assert first is not None and first > 0.0
    # only the FIRST served tick defines time-to-first-tick
    engine.submit(GenerationRequest(request_id=1, seed=2, steps=2), now=0.0)
    engine.run_until_idle(now=0.0)
    assert engine.metrics.first_tick_s == first
    s = engine.metrics.summary()
    assert s['warmup_s'] == pytest.approx(dt)
    assert s['first_tick_s'] == pytest.approx(first)


# ---------------------------------------------------------------------------
# AOT pre-lowering
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_step_variants_enumeration(pipe):
    plain = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    assert plain.step_variants(('fp32',)) == [('fp32', False, None)]
    assert len(plain.step_variants(('fp32', 'w8a8'))) == 2

    cached = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0,
                                      cache_interval=2)
    assert cached.step_variants(('fp32',)) == [('fp32', False, True),
                                               ('fp32', False, False)]

    ctx_cfg = UNetConfig('tiny-cold-ctx', img_size=16, in_ch=3, base_ch=32,
                         ch_mults=(1, 2), n_res_blocks=1,
                         attn_resolutions=(8,), n_heads=4, timesteps=16,
                         context_dim=8)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), ctx_cfg)
    ctx = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 8))
    guided = ContinuousBatchingEngine(p, slots=2, context=ctx,
                                      quality_probe=0, cache_interval=2)
    # 2 precisions x {unguided, guided} x {refresh, skip} = 8
    assert len(guided.step_variants(('fp32', 'w8a8'))) == 8


@pytest.mark.coldstart
def test_aot_warmup_compiles_and_persists(pipe):
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    expected = len(engine.step_variants(('fp32',))) + 3  # + helpers
    with tempfile.TemporaryDirectory() as d:
        try:
            info = engine.aot_warmup(precisions=('fp32',), cache_dir=d)
            assert info['variants'] == expected
            assert info['seconds'] > 0.0
            assert cache_entries(d) > 0          # executables on disk
        finally:
            disable_persistent_cache()
    # the AOT-warmed engine actually serves
    engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
    results = engine.run_until_idle(now=0.0)
    assert [r.request_id for r in results] == [0]


# ---------------------------------------------------------------------------
# real process restart: cold populates, warm loads
# ---------------------------------------------------------------------------

_CHILD = r"""
import json, os, sys
os.environ['JAX_PLATFORMS'] = 'cpu'
import jax
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           cache_entries)
cfg = UNetConfig('tiny-cold', img_size=16, in_ch=3, base_ch=32,
                 ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                 n_heads=4, timesteps=16)
pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
warmup_s = engine.warmup(cache_dir=sys.argv[1])
engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
assert len(engine.run_until_idle(now=0.0)) == 1
print(json.dumps({'warmup_s': warmup_s,
                  'entries': cache_entries(sys.argv[1])}))
"""


def _restart(cache_dir):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), '..', 'src')
    env['PYTHONPATH'] = os.path.abspath(src) + (
        os.pathsep + env['PYTHONPATH'] if env.get('PYTHONPATH') else '')
    env['JAX_PLATFORMS'] = 'cpu'
    out = subprocess.run([sys.executable, '-c', _CHILD, cache_dir],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.coldstart
def test_cold_then_warm_restart_uses_persistent_cache():
    """Two fresh processes share one cache dir: the cold run persists
    every executable, the warm run adds none and warms up faster."""
    with tempfile.TemporaryDirectory() as d:
        cold = _restart(d)
        assert cold['entries'] > 0, 'cold warmup persisted nothing'
        warm = _restart(d)
        assert warm['entries'] == cold['entries'], \
            'warm restart recompiled (new cache entries appeared)'
        assert warm['warmup_s'] < cold['warmup_s'], \
            (f"warm warmup {warm['warmup_s']:.2f}s not faster than "
             f"cold {cold['warmup_s']:.2f}s")


# ---------------------------------------------------------------------------
# persistent-cache size bound (LRU eviction)
# ---------------------------------------------------------------------------

def _fake_entry(d, name, size, age_s):
    """A fake cache entry `age_s` old (atime == mtime == now - age_s)."""
    path = os.path.join(d, name)
    with open(path, 'wb') as f:
        f.write(b'\0' * size)
    import time
    t = time.time() - age_s
    os.utime(path, (t, t))
    return path


@pytest.mark.coldstart
def test_trim_cache_evicts_lru_until_under_budget(tmp_path):
    """The size bound evicts least-recently-used entries first and stops
    as soon as the directory fits; eviction counts surface through
    cache_entries(with_evictions=True)."""
    from repro.serving import cache_entries, cache_evictions, trim_cache
    d = str(tmp_path)
    _fake_entry(d, 'oldest', 400, age_s=300)
    _fake_entry(d, 'middle', 400, age_s=200)
    _fake_entry(d, 'newest', 400, age_s=100)
    ev0 = cache_evictions()
    assert trim_cache(d, max_bytes=2000) == 0          # already fits
    assert trim_cache(d, max_bytes=800) == 1           # oldest goes
    assert sorted(os.listdir(d)) == ['middle', 'newest']
    assert trim_cache(d, max_bytes=100) == 2           # both go
    assert os.listdir(d) == []
    n, evicted = cache_entries(d, with_evictions=True)
    assert n == 0 and evicted - ev0 == 3


@pytest.mark.coldstart
def test_trim_cache_noop_without_bound_or_dir(tmp_path):
    from repro.serving import trim_cache
    assert trim_cache(str(tmp_path), max_bytes=None) == 0
    assert trim_cache(str(tmp_path / 'missing'), max_bytes=10) == 0


@pytest.mark.coldstart
def test_enable_with_max_bytes_trims_and_persists_bound(tmp_path):
    """enable_persistent_cache(max_bytes=...) trims immediately, and an
    idempotent re-enable without max_bytes (what engine.warmup does)
    keeps the configured bound instead of clobbering it."""
    from repro.serving import compile_cache as cc
    d = str(tmp_path / 'cache')
    os.makedirs(d)
    _fake_entry(d, 'a', 600, age_s=60)
    _fake_entry(d, 'b', 600, age_s=30)
    try:
        cc.enable_persistent_cache(d, max_bytes=700)
        assert os.listdir(d) == ['b']                  # trimmed on enable
        cc.enable_persistent_cache(d)                  # warmup's re-enable
        _fake_entry(d, 'c', 600, age_s=0)
        cc.trim_cache()                                # bound still active
        assert os.listdir(d) == ['c']
    finally:
        cc.disable_persistent_cache()
