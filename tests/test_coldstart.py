"""Cold-start hardening tests: persistent compilation cache wiring,
warmup/first-tick accounting, AOT pre-lowering of every step variant,
and a real process-restart check (cold populates the cache, warm loads
from it and is faster)."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           active_cache_dir, cache_entries,
                           disable_persistent_cache,
                           enable_persistent_cache)

TINY = UNetConfig('tiny-cold', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


# ---------------------------------------------------------------------------
# compile_cache wiring
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_enable_persistent_cache_configures_jax():
    with tempfile.TemporaryDirectory() as d:
        target = os.path.join(d, 'xla-cache')
        try:
            path = enable_persistent_cache(target)
            assert os.path.isdir(path)
            assert active_cache_dir() == path
            assert jax.config.jax_compilation_cache_dir == path
            assert cache_entries() == 0          # enabled, nothing stored
        finally:
            disable_persistent_cache()
        assert active_cache_dir() is None
        assert jax.config.jax_compilation_cache_dir is None


@pytest.mark.coldstart
def test_cache_entries_handles_missing_and_inactive():
    assert cache_entries('/nonexistent/no-such-cache-dir') == 0
    assert active_cache_dir() is None
    assert cache_entries() == 0                  # nothing active


# ---------------------------------------------------------------------------
# warmup / first-tick accounting
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_warmup_and_first_tick_recorded(pipe):
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    dt = engine.warmup()
    assert dt > 0.0
    assert engine.metrics.warmup_s == pytest.approx(dt)
    assert engine.metrics.first_tick_s is None   # nothing served yet
    engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
    engine.run_until_idle(now=0.0)
    first = engine.metrics.first_tick_s
    assert first is not None and first > 0.0
    # only the FIRST served tick defines time-to-first-tick
    engine.submit(GenerationRequest(request_id=1, seed=2, steps=2), now=0.0)
    engine.run_until_idle(now=0.0)
    assert engine.metrics.first_tick_s == first
    s = engine.metrics.summary()
    assert s['warmup_s'] == pytest.approx(dt)
    assert s['first_tick_s'] == pytest.approx(first)


# ---------------------------------------------------------------------------
# AOT pre-lowering
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.coldstart
def test_step_variants_enumeration(pipe):
    plain = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    assert plain.step_variants(('fp32',)) == [('fp32', False, None)]
    assert len(plain.step_variants(('fp32', 'w8a8'))) == 2

    cached = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0,
                                      cache_interval=2)
    assert cached.step_variants(('fp32',)) == [('fp32', False, True),
                                               ('fp32', False, False)]

    ctx_cfg = UNetConfig('tiny-cold-ctx', img_size=16, in_ch=3, base_ch=32,
                         ch_mults=(1, 2), n_res_blocks=1,
                         attn_resolutions=(8,), n_heads=4, timesteps=16,
                         context_dim=8)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), ctx_cfg)
    ctx = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 8))
    guided = ContinuousBatchingEngine(p, slots=2, context=ctx,
                                      quality_probe=0, cache_interval=2)
    # 2 precisions x {unguided, guided} x {refresh, skip} = 8
    assert len(guided.step_variants(('fp32', 'w8a8'))) == 8


@pytest.mark.coldstart
def test_aot_warmup_compiles_and_persists(pipe):
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    expected = len(engine.step_variants(('fp32',))) + 3  # + helpers
    with tempfile.TemporaryDirectory() as d:
        try:
            info = engine.aot_warmup(precisions=('fp32',), cache_dir=d)
            assert info['variants'] == expected
            assert info['seconds'] > 0.0
            assert cache_entries(d) > 0          # executables on disk
        finally:
            disable_persistent_cache()
    # the AOT-warmed engine actually serves
    engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
    results = engine.run_until_idle(now=0.0)
    assert [r.request_id for r in results] == [0]


# ---------------------------------------------------------------------------
# real process restart: cold populates, warm loads
# ---------------------------------------------------------------------------

_CHILD = r"""
import json, os, sys
os.environ['JAX_PLATFORMS'] = 'cpu'
import jax
from repro.diffusion.pipeline import DiffusionPipeline
from repro.models.unet import UNetConfig
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           cache_entries)
cfg = UNetConfig('tiny-cold', img_size=16, in_ch=3, base_ch=32,
                 ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                 n_heads=4, timesteps=16)
pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
warmup_s = engine.warmup(cache_dir=sys.argv[1])
engine.submit(GenerationRequest(request_id=0, seed=1, steps=2), now=0.0)
assert len(engine.run_until_idle(now=0.0)) == 1
print(json.dumps({'warmup_s': warmup_s,
                  'entries': cache_entries(sys.argv[1])}))
"""


def _restart(cache_dir):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), '..', 'src')
    env['PYTHONPATH'] = os.path.abspath(src) + (
        os.pathsep + env['PYTHONPATH'] if env.get('PYTHONPATH') else '')
    env['JAX_PLATFORMS'] = 'cpu'
    out = subprocess.run([sys.executable, '-c', _CHILD, cache_dir],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.coldstart
def test_cold_then_warm_restart_uses_persistent_cache():
    """Two fresh processes share one cache dir: the cold run persists
    every executable, the warm run adds none and warms up faster."""
    with tempfile.TemporaryDirectory() as d:
        cold = _restart(d)
        assert cold['entries'] > 0, 'cold warmup persisted nothing'
        warm = _restart(d)
        assert warm['entries'] == cold['entries'], \
            'warm restart recompiled (new cache entries appeared)'
        assert warm['warmup_s'] < cold['warmup_s'], \
            (f"warm warmup {warm['warmup_s']:.2f}s not faster than "
             f"cold {cold['warmup_s']:.2f}s")
