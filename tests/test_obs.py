"""Observability tests: tracer semantics, zero-cost-when-disabled,
JSONL/Chrome exporters (strict JSON), Prometheus exposition, the
snapshot reporter, metrics summary symmetry (p99 + shed breakdown) and
the engine end-to-end trace <-> metrics reconciliation."""
import json

import jax
import numpy as np
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.distributed.fault_tolerance import StepMonitor
from repro.models.unet import UNetConfig
from repro.obs import (NULL_TRACER, SnapshotReporter, Tracer, chrome_trace,
                       read_jsonl, render_exposition, sanitize,
                       write_chrome_trace, write_jsonl)
from repro.obs.export import QUEUE_TID, SCHEDULER_TID
from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                           GenerationRequest, GenerationResult,
                           ServingMetrics)

pytestmark = pytest.mark.obs

TINY = UNetConfig('tiny-obs', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


def _strict(text):
    """json.loads that rejects NaN/Infinity tokens."""
    def boom(tok):
        raise AssertionError(f'non-strict JSON token {tok!r}')
    return json.loads(text, parse_constant=boom)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_tracer_records_ordered_nested_spans():
    tr = Tracer()
    with tr.region('outer', cat='engine'):
        tr.instant('mark', cat='engine')
        with tr.region('inner', cat='engine'):
            pass
    names = [e.name for e in tr.events]
    # instants append immediately; spans append at region exit, so the
    # inner span lands before the outer one
    assert names == ['mark', 'inner', 'outer']
    inner, outer = tr.spans('inner')[0], tr.spans('outer')[0]
    assert outer.ts <= inner.ts
    assert outer.ts + outer.dur >= inner.ts + inner.dur
    assert all(e.ph == 'X' for e in tr.spans())
    assert len(tr) == 3


@pytest.mark.smoke
def test_tracer_explicit_timestamps_and_select():
    tr = Tracer()
    tr.instant('shed', cat='queue', ts=1.5, rid=7, reason='queue_full')
    tr.complete('request', 1.0, 3.0, cat='request', rid=7)
    tr.counter('occupancy', ts=2.0, active=3, queued=1)
    assert tr.select('shed')[0].ts == 1.5
    assert tr.spans('request')[0].dur == 2.0
    assert tr.select(ph='C')[0].args == {'active': 3, 'queued': 1}
    # negative-duration spans clamp to zero rather than corrupting a view
    assert tr.complete('bad', 5.0, 4.0).dur == 0.0


@pytest.mark.smoke
def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert Tracer().enabled is True
    before = len(NULL_TRACER)
    assert NULL_TRACER.instant('x') is None
    assert NULL_TRACER.complete('x', 0.0, 1.0) is None
    assert NULL_TRACER.counter('x', v=1) is None
    with NULL_TRACER.region('x'):
        pass
    assert len(NULL_TRACER) == before == 0


@pytest.mark.smoke
def test_trace_event_to_dict_drops_none_ids():
    tr = Tracer()
    e = tr.instant('submit', cat='queue', ts=0.5, rid=3)
    d = e.to_dict()
    assert d['rid'] == 3
    assert 'slot' not in d and 'device' not in d and 'tick' not in d
    assert 'dur' not in d                     # instants carry no duration
    s = tr.complete('step', 0.0, 0.25, cat='tick', tick=4).to_dict()
    assert s['dur'] == 0.25 and s['tick'] == 4


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_sanitize_rewrites_non_finite_floats():
    out = sanitize({'a': float('nan'), 'b': [1.0, float('inf')],
                    'c': {'d': -float('inf'), 'e': 'txt'}, 'f': 3})
    assert out == {'a': None, 'b': [1.0, None],
                   'c': {'d': None, 'e': 'txt'}, 'f': 3}


@pytest.mark.smoke
def test_jsonl_roundtrip(tmp_path):
    tr = Tracer()
    tr.instant('submit', cat='queue', ts=0.1, rid=0, psnr=float('nan'))
    tr.complete('request', 0.1, 0.9, cat='request', rid=0, slot=1)
    path = str(tmp_path / 'events.jsonl')
    assert write_jsonl(tr, path) == 2
    lines = open(path).read().splitlines()
    assert len(lines) == 2
    for line in lines:
        _strict(line)                          # every line is strict JSON
    back = read_jsonl(path)
    assert back[0]['name'] == 'submit'
    assert back[0]['args']['psnr'] is None     # NaN -> null
    assert back[1]['dur'] == pytest.approx(0.8)


@pytest.mark.smoke
def test_chrome_trace_lanes_and_strict_json(tmp_path):
    tr = Tracer()
    tr.instant('submit', cat='queue', ts=0.0, rid=0)
    tr.complete('request', 0.0, 1.0, cat='request', rid=0, slot=2,
                device=1, psnr=float('nan'))
    tr.complete('tick', 0.0, 0.5, cat='tick', tick=0)
    doc = chrome_trace(tr)
    rows = doc['traceEvents']
    by_name = {r['name']: r for r in rows if r['ph'] not in 'M'}
    # lane mapping: queue -> QUEUE_TID, slot-scoped -> 1+slot, else sched
    assert by_name['submit']['tid'] == QUEUE_TID
    assert by_name['request']['tid'] == 3
    assert by_name['tick']['tid'] == SCHEDULER_TID
    # seconds -> microseconds, instants scoped to their thread
    assert by_name['request']['dur'] == pytest.approx(1e6)
    assert by_name['submit']['s'] == 't'
    assert by_name['request']['args']['psnr'] is None
    assert by_name['request']['args']['rid'] == 0
    meta = {r['args']['name'] for r in rows if r['ph'] == 'M'}
    assert {'serving engine', 'scheduler', 'queue',
            'slot 2 (dev 1)'} <= meta
    path = str(tmp_path / 'trace.json')
    assert write_chrome_trace(tr, path) == len(rows)
    _strict(open(path).read())


# ---------------------------------------------------------------------------
# metrics symmetry + exposition
# ---------------------------------------------------------------------------

def _result(rid, submit=0.0, start=0.5, finish=1.0, **kw):
    return GenerationResult(request_id=rid, image=np.zeros((2, 2, 3)),
                            steps=4, submit_time=submit, start_time=start,
                            finish_time=finish, **kw)


@pytest.mark.smoke
def test_percentile_edge_cases():
    assert ServingMetrics._percentile([], 50) == 0.0
    assert ServingMetrics._percentile([2.5], 99) == 2.5


@pytest.mark.smoke
def test_summary_p99_and_shed_breakdown():
    m = ServingMetrics()
    for i in range(4):
        m.record_submit(0.0)
        m.record_complete(_result(i, finish=1.0 + i))
    m.record_shed('queue_full')
    m.record_shed('queue_full')
    m.record_shed('expired')
    s = m.summary()
    assert s['p99_latency_ms'] == pytest.approx(4000.0)
    assert s['p99_latency_ms'] >= s['p95_latency_ms'] >= s['p50_latency_ms']
    assert s['shed'] == 3.0
    assert s['shed_queue_full'] == 2.0
    assert s['shed_expired'] == 1.0
    snap = m.snapshot()
    assert snap.p99_latency_s >= snap.p95_latency_s


@pytest.mark.smoke
def test_render_exposition_format():
    m = ServingMetrics()
    m.record_submit(0.0)
    m.record_complete(_result(0), slo_ms=100.0)
    m.record_shed('queue_full')
    text = render_exposition(m, active_slots=2, queued=1)
    lines = text.splitlines()
    assert '# HELP repro_serving_completed_total Requests completed' in lines
    assert '# TYPE repro_serving_completed_total counter' in lines
    assert 'repro_serving_completed_total 1' in lines
    assert 'repro_serving_shed_total{reason="queue_full"} 1' in lines
    assert 'repro_serving_active_slots 2' in lines
    assert 'repro_serving_queued 1' in lines
    assert any(l.startswith('repro_serving_latency_seconds'
                            '{quantile="0.99"}') for l in lines)
    assert 'repro_serving_latency_seconds_count 1' in lines
    # summary _sum accumulates the raw latency, not a percentile
    assert 'repro_serving_latency_seconds_sum 1' in lines
    # every sample line's metric name was declared by a HELP/TYPE pair
    declared = {l.split(' ')[2] for l in lines if l.startswith('# TYPE')}
    for line in lines:
        if line.startswith('#'):
            continue
        name = line.split('{')[0].split(' ')[0]
        base = name[:-len('_sum')] if name.endswith('_sum') else (
            name[:-len('_count')] if name.endswith('_count') else name)
        assert base in declared, f'undeclared sample {name}'


@pytest.mark.smoke
def test_snapshot_reporter_interval_and_force():
    clock = [0.0]
    out = []
    rep = SnapshotReporter(interval_s=5.0, emit=out.append,
                           clock=lambda: clock[0])
    m = ServingMetrics()
    m.record_submit(0.0)
    m.record_complete(_result(0))
    # first call arms the interval without reporting
    assert rep.maybe_report(metrics=m) is None
    clock[0] = 3.0
    assert rep.maybe_report(metrics=m) is None
    clock[0] = 6.0
    line = rep.maybe_report(metrics=m, active_slots=1, queued=2)
    assert line is not None and 'completed=1/1' in line
    assert 'active=1' in line and 'queued=2' in line
    assert rep.maybe_report(metrics=m, force=True) is not None
    assert out == [line, line] or len(out) == 2
    assert rep.reports == 2
    with pytest.raises(ValueError):
        SnapshotReporter(interval_s=0.0)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_trace_reconciles_with_metrics(pipe):
    """The acceptance invariant: a traced run's request spans agree with
    the metrics ledger — same completed count, identical per-request
    latency (spans are stamped from the result's own timing fields) —
    and every shed request has exactly one attributed shed instant."""
    tr = Tracer()
    engine = ContinuousBatchingEngine(
        pipe, slots=2, quality_probe=0, tracer=tr,
        queue=AdmissionQueue(max_depth=1))
    for i in range(6):
        engine.submit(GenerationRequest(request_id=i, seed=i, steps=3),
                      now=0.0)
    results = engine.run_until_idle(now=0.0, tick_dt=0.01)
    m = engine.metrics
    assert m.completed == len(results) > 0
    assert engine.queue.shed > 0

    spans = tr.spans('request')
    assert len(spans) == m.completed
    for s in spans:
        res = next(r for r in results if r.request_id == s.rid)
        assert s.dur == pytest.approx(res.latency_s, abs=1e-9)
        assert s.args['trace_id'] == f'req-{s.rid}'
        assert s.args['precision'] == 'fp32'
    sheds = tr.select('shed')
    assert len(sheds) == engine.queue.shed
    assert all(e.args['reason'] == 'queue_full' for e in sheds)
    # request-lifecycle instants pair off with the admitted population
    assert len(tr.select('submit')) == m.submitted
    assert len(tr.select('slot_assign')) == m.completed
    assert len(tr.select('decode_dispatch')) == m.completed
    assert len(tr.select('decode_done')) == m.completed
    assert len(tr.select('complete')) == m.completed
    # step spans cover every tick's dispatches and carry energy deltas
    steps = tr.spans('step')
    assert steps and all(s.args['energy_j'] > 0 for s in steps)
    assert sum(s.args['slots'] for s in steps) == m.unet_steps
    ticks = tr.spans('tick')
    assert len(ticks) == m.ticks
    occ = tr.select('occupancy', ph='C')
    assert len(occ) == m.ticks
    assert all(set(e.args) == {'active', 'queued'} for e in occ)


def test_engine_default_tracer_records_nothing(pipe):
    before = len(NULL_TRACER)
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    assert engine.tracer is NULL_TRACER
    engine.submit(GenerationRequest(request_id=0, seed=0, steps=2), now=0.0)
    engine.run_until_idle(now=0.0)
    assert len(NULL_TRACER) == before == 0


def test_engine_warmup_not_traced(pipe):
    """Warmup's throwaway requests must not pollute the trace: the only
    record is one engine-scoped warmup span."""
    tr = Tracer()
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0,
                                      tracer=tr)
    engine.warmup()
    assert tr.spans('request') == []
    assert tr.select('submit') == []
    warm = tr.spans('warmup')
    assert len(warm) == 1
    assert warm[0].args['seconds'] > 0


def test_trace_id_threads_through(pipe):
    tr = Tracer()
    engine = ContinuousBatchingEngine(pipe, slots=1, quality_probe=0,
                                      tracer=tr)
    engine.submit(GenerationRequest(request_id=0, seed=0, steps=2,
                                    trace_id='gateway-abc'), now=0.0)
    res = engine.run_until_idle(now=0.0)[0]
    assert res.trace_id == 'gateway-abc'
    assert tr.spans('request')[0].args['trace_id'] == 'gateway-abc'
    assert tr.select('submit')[0].args['trace_id'] == 'gateway-abc'


def test_straggler_callback_edge_triggered(pipe):
    """on_straggler fires once per flagged-set CHANGE, with a matching
    trace instant — a persistent straggler does not refire every tick."""
    tr = Tracer()
    calls = []
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0,
                                      tracer=tr,
                                      on_straggler=calls.append)
    engine.monitor = StepMonitor(n_hosts=4, window=4, min_samples=2)
    for _ in range(4):
        for host in (0, 1, 2):
            engine.monitor.record(host, 0.010)
        engine.monitor.record(3, 0.100)       # 10x the fleet median
    report = engine._poll_straggler()
    assert report is not None and report.slow_hosts == [3]
    assert [r.slow_hosts for r in calls] == [[3]]
    # same flagged set again: edge-triggered, no refire
    engine._poll_straggler()
    assert len(calls) == 1
    ev = tr.select('straggler')
    assert len(ev) == 1
    assert ev[0].args['slow_devices'] == [3]
    assert 're-mesh' in ev[0].args['recommendation']


def test_shed_attribution_per_request(pipe):
    """Expired requests are attributed by id in the trace (the queue's
    on_shed hook), not just counted."""
    tr = Tracer()
    engine = ContinuousBatchingEngine(pipe, slots=1, quality_probe=0,
                                      tracer=tr)
    engine.submit(GenerationRequest(request_id=0, seed=0, steps=2),
                  now=0.0)
    engine.submit(GenerationRequest(request_id=1, seed=1, steps=2,
                                    slo_ms=50.0), now=0.0)
    # tick far past request 1's deadline: it expires at admission
    results = engine.run_until_idle(now=10.0, tick_dt=0.01)
    assert [r.request_id for r in results] == [0]
    sheds = tr.select('shed')
    assert len(sheds) == 1
    assert sheds[0].rid == 1 and sheds[0].args['reason'] == 'expired'
    assert engine.metrics.shed_by_reason == {'expired': 1}


def test_user_on_shed_hook_chains(pipe):
    """A caller-installed queue on_shed still fires after the engine
    wires its own (trace + metrics) hook in."""
    seen = []
    q = AdmissionQueue(max_depth=1,
                       on_shed=lambda reason, req, now:
                       seen.append((reason, req.request_id)))
    engine = ContinuousBatchingEngine(pipe, slots=1, quality_probe=0,
                                      queue=q)
    for i in range(3):
        engine.submit(GenerationRequest(request_id=i, seed=i, steps=2),
                      now=0.0)
    engine.run_until_idle(now=0.0)
    assert seen == [('rejected', 2)] or seen == [('rejected', 1),
                                                 ('rejected', 2)]
    assert engine.metrics.shed_by_reason.get('queue_full') == len(seen)
