"""Distributed-runtime tests: sharding rules, checkpoint/restart, elastic
resharding, fault-tolerance logic, and an 8-virtual-device end-to-end train
(via subprocess, since device count locks at first jax init)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StepMonitor, elastic_plan)

SRC = os.path.join(os.path.dirname(__file__), '..', 'src')


def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f'--xla_force_host_platform_device_count={devices}',
               PYTHONPATH=SRC, JAX_PLATFORMS='cpu')
    out = subprocess.run([sys.executable, '-c', textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_pspecs_rules():
    out = _run_py('''
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed．sharding import param_pspecs
        from repro.configs.registry import get
        from repro.launch.steps import init_params
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg = get('internlm2-1.8b')
        params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        specs = param_pspecs(params, mesh)
        assert specs['embed']['table'] == P('model', 'data'), specs['embed']
        blk = specs['blocks']['sub0']
        assert blk['attn']['wq']['w'] == P(None, 'data', 'model')
        assert blk['attn']['wo']['w'] == P(None, 'model', 'data')
        assert blk['mlp']['down']['w'] == P(None, 'model', 'data')
        assert blk['mix_norm']['scale'] == P(None, None)
        print('SPEC-OK')
    '''.replace('．', '.'))
    assert 'SPEC-OK' in out


def test_end_to_end_sharded_training_8dev():
    """Real (tiny) sharded training on an 8-virtual-device (2,4) mesh:
    loss decreases, params stay sharded."""
    out = _run_py('''
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.launch.train import Trainer
        from repro.optim.adamw import AdamWConfig
        from repro.configs.registry import smoke_config
        from repro.data.pipeline import TokenPipelineConfig
        import dataclasses
        cfg = dataclasses.replace(smoke_config('internlm2-1.8b'),
                                  d_model=64, vocab=256)
        mesh = make_mesh((2, 4), ('data', 'model'))
        tr = Trainer(cfg, mesh, AdamWConfig(lr=3e-3, warmup_steps=2,
                                            total_steps=20))
        data = TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                   global_batch=8)
        losses = tr.run(data, steps=15, log_every=100)
        assert losses[-1] < losses[0], (losses[0], losses[-1])
        shard_counts = {len(x.sharding.device_set)
                        for x in jax.tree_util.tree_leaves(tr.params)}
        assert 8 in shard_counts      # params live on the full mesh
        print('TRAIN-OK', losses[0], '->', losses[-1])
    ''')
    assert 'TRAIN-OK' in out


def test_checkpoint_restart_and_elastic_reshard_8dev():
    """Save on a (2,4) mesh, restore onto a (4,2) mesh (elastic re-mesh) and
    onto (1,1); training resumes bit-compatibly on the same mesh."""
    out = _run_py('''
        import jax, jax.numpy as jnp, numpy as np, tempfile, dataclasses
        from repro.launch.mesh import make_mesh
        from repro.launch.train import Trainer
        from repro.optim.adamw import AdamWConfig
        from repro.configs.registry import smoke_config
        from repro.data.pipeline import TokenPipelineConfig
        cfg = dataclasses.replace(smoke_config('internlm2-1.8b'),
                                  d_model=64, vocab=256)
        data = TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                   global_batch=8)
        d = tempfile.mkdtemp()
        mesh1 = make_mesh((2, 4), ('data', 'model'))
        tr1 = Trainer(cfg, mesh1, AdamWConfig(), ckpt_dir=d)
        tr1.run(data, steps=3, ckpt_every=100, log_every=100)
        tr1.save(3, blocking=True)
        # elastic restart on a DIFFERENT mesh
        mesh2 = make_mesh((4, 2), ('data', 'model'))
        tr2 = Trainer(cfg, mesh2, AdamWConfig(), ckpt_dir=d)
        tr2.maybe_restore()
        assert tr2.start_step == 3
        a = jax.tree_util.tree_leaves(tr1.params)[0]
        b = jax.tree_util.tree_leaves(tr2.params)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('ELASTIC-OK')
    ''')
    assert 'ELASTIC-OK' in out


# ---------------------------------------------------------------------------
# checkpoint manager (single process)
# ---------------------------------------------------------------------------

def test_checkpoint_atomicity_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {'w': jnp.arange(6.0).reshape(2, 3), 's': jnp.int32(7)}
    for step in (1, 2, 3):
        m.save(step, tree, blocking=True)
    assert m.latest_step() == 3
    # keep=2 -> step 1 collected
    assert not os.path.exists(str(tmp_path / 'step_00000001'))
    restored = m.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(restored['w']),
                                  np.asarray(tree['w']))
    # uncommitted dir is ignored
    os.makedirs(str(tmp_path / 'step_00000099'))
    assert m.latest_step() == 3


def test_checkpoint_async(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = {'w': jnp.ones((128, 128))}
    m.save(5, tree, blocking=False)
    m.wait()
    assert m.latest_step() == 5


# ---------------------------------------------------------------------------
# fault tolerance logic
# ---------------------------------------------------------------------------

def test_straggler_detection():
    mon = StepMonitor(n_hosts=4, window=16, threshold=1.5, min_samples=4)
    for _ in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 2.5)
    rep = mon.check()
    assert rep is not None and rep.slow_hosts == [2]
    assert 're-mesh' in rep.recommendation


def test_straggler_no_false_positive():
    mon = StepMonitor(n_hosts=4, min_samples=4)
    for _ in range(8):
        for h in range(4):
            mon.record(h, 1.0 + 0.01 * h)
    assert mon.check() is None


def test_elastic_plan():
    shape, axes = elastic_plan(64)           # 512 chips
    assert shape == (2, 16, 16) and axes == ('pod', 'data', 'model')
    shape, axes = elastic_plan(62)           # lost 2 hosts -> 496 chips
    assert shape == (31, 16)                 # sheds a pod, keeps TP
    with pytest.raises(ValueError):
        elastic_plan(1, model_parallel=16)


def test_preemption_flag():
    h = PreemptionHandler(install=False)
    assert not h.preempted
    h._handler(15, None)
    assert h.preempted


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_data_pipeline_deterministic_and_sharded():
    from repro.data.pipeline import TokenPipelineConfig, token_batch
    cfg = TokenPipelineConfig(vocab=128, seq_len=16, global_batch=8)
    a = token_batch(cfg, step=3)
    b = token_batch(cfg, step=3)
    np.testing.assert_array_equal(np.asarray(a['tokens']),
                                  np.asarray(b['tokens']))
    c = token_batch(cfg, step=4)
    assert not np.array_equal(np.asarray(a['tokens']),
                              np.asarray(c['tokens']))
    # host shards partition the batch deterministically
    s0 = token_batch(cfg, 3, shard=(0, 2))
    s1 = token_batch(cfg, 3, shard=(1, 2))
    assert s0['tokens'].shape == (4, 16)
    assert not np.array_equal(np.asarray(s0['tokens']),
                              np.asarray(s1['tokens']))


# ---------------------------------------------------------------------------
# shard_hint / current_mesh (regression: the thread-resources fallback was
# dead code because one try-block guarded both mesh probes)
# ---------------------------------------------------------------------------

def test_shard_hint_constrains_under_legacy_mesh_context():
    """Inside a legacy ``with mesh:`` block, shard_hint must discover the
    ambient mesh (via the pxla thread-resources probe on JAX releases
    without ``get_abstract_mesh``) and lower to a real sharding
    constraint — the HLO carries the constraint custom-call."""
    out = _run_py('''
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.distributed．sharding import current_mesh, shard_hint
        mesh = make_mesh((8,), ('data',))
        assert current_mesh() is None         # no ambient mesh yet
        with mesh:
            assert current_mesh() is not None
            assert 'data' in current_mesh().axis_names
            fn = jax.jit(lambda x: shard_hint(x, 'data') * 2.0)
            txt = fn.lower(
                jax.ShapeDtypeStruct((16, 4), jnp.float32)).as_text()
            assert 'Sharding' in txt, txt[:2000]
            y = fn(jnp.ones((16, 4)))
            assert 'data' in str(y.sharding.spec)
        print('HINT-OK')
    '''.replace('．', '.'))
    assert 'HINT-OK' in out


def test_shard_hint_explicit_mesh_outside_context():
    """The serving engine passes its mesh explicitly from plain eager
    code — no ``with mesh:`` anywhere — and the constraint must still
    apply (concrete NamedSharding, not a bare PartitionSpec)."""
    out = _run_py('''
        import jax, jax.numpy as jnp
        from repro.launch.mesh import serving_mesh
        from repro.distributed．sharding import shard_hint
        mesh = serving_mesh(8)
        fn = jax.jit(lambda x: shard_hint(x, 'data', mesh=mesh) + 1.0)
        y = fn(jnp.ones((8, 4)))
        assert 'data' in str(y.sharding.spec), y.sharding
        # non-dividing dims drop the axis instead of failing
        z = jax.jit(lambda x: shard_hint(x, 'data', mesh=mesh))(
            jnp.ones((6, 4)))
        assert z.sharding.is_fully_replicated or \
            'data' not in str(z.sharding.spec)
        print('EXPLICIT-OK')
    '''.replace('．', '.'))
    assert 'EXPLICIT-OK' in out


def test_shard_hint_identity_without_mesh():
    from repro.distributed.sharding import shard_hint
    x = jnp.ones((4, 4))
    assert shard_hint(x, 'data') is x        # no ambient mesh: identity
