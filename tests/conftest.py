import os
import sys

# Keep tests single-device (the dry-run sets its own device count in a
# subprocess).  Force deterministic, quiet CPU execution.
os.environ.setdefault('JAX_PLATFORMS', 'cpu')

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))
