"""Sharded multi-device serving tests (8 simulated CPU devices).

The device count locks at first jax init, so every mesh scenario runs in
ONE subprocess (module-scope fixture) under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and reports a
JSON blob; the tests here assert on it.  Scenarios:

  * slot-sharded engine matches the single-device engine per request
    (atol 1e-5 — one-ulp XLA fusion differences between the batch-N
    kernel and the per-device kernels preclude bitwise identity) and is
    bitwise DETERMINISTIC across two sharded runs;
  * zero recompiles after one warmup, serving on the mesh;
  * decode overlap: results surface with overlapped decodes counted;
  * elastic 8 -> 4 resize mid-flight completes every request (overflow
    parks and re-enters) and 4 -> 8 grows back;
  * shed accounting reconciles under a bounded queue and under
    service-time-aware expiry: completed + shed == offered.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.fault_tolerance import elastic_serving_plan
from repro.serving import align_slots

SRC = os.path.join(os.path.dirname(__file__), '..', 'src')

pytestmark = pytest.mark.dist_serving

_CHILD = '''
import json
import jax, numpy as np
from repro.models.unet import UNetConfig
from repro.diffusion.pipeline import DiffusionPipeline
from repro.launch.mesh import serving_mesh
from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                           GenerationRequest)

TINY = UNetConfig('tiny-dist', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)
pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)
report = {'n_devices': jax.device_count()}

def reqs(n, steps=5, start=0, **kw):
    return [GenerationRequest(request_id=start + i, seed=100 + start + i,
                              steps=steps, exit_tol=0.0, **kw)
            for i in range(n)]

def reqs_var(n, start=0):
    # staggered step counts so drains happen while others still step —
    # the decode-overlap window
    return [GenerationRequest(request_id=start + i, seed=100 + start + i,
                              steps=4 + i % 3, exit_tol=0.0)
            for i in range(n)]

def serve(engine, requests, now=0.0):
    out = []
    for r in requests:
        engine.submit(r, now=now)
    out.extend(engine.run_until_idle(now=now))
    return {r.request_id: r.image for r in out}

# --- single-device reference -------------------------------------------
e1 = ContinuousBatchingEngine(pipe, slots=8, quality_probe=0)
e1.warmup()
ref = serve(e1, reqs_var(6))

# --- sharded engine: parity, zero recompiles, overlap ------------------
def sharded_run():
    e = ContinuousBatchingEngine(pipe, slots_per_device=1,
                                 mesh=serving_mesh(8), quality_probe=0)
    e.warmup()
    stats0 = e.compile_stats()
    imgs = serve(e, reqs_var(6))
    return e, stats0, imgs

e8, stats0, imgs = sharded_run()
report['slots'] = e8.slots
report['overlap_default_on'] = e8.overlap_decode
report['x_sharded'] = 'data' in str(e8.x.sharding.spec)
report['recompiles'] = {k: (stats0.get(k), v)
                        for k, v in e8.compile_stats().items()
                        if stats0.get(k) != v}
report['max_abs_diff'] = max(
    float(np.abs(ref[i] - imgs[i]).max()) for i in ref)
report['overlapped_decodes'] = e8.metrics.overlapped_decodes
report['all_completed'] = sorted(imgs) == sorted(ref)

_, _, imgs2 = sharded_run()
report['deterministic'] = all(np.array_equal(imgs[i], imgs2[i])
                              for i in imgs)

# --- elastic 8 -> 4 -> 8 with in-flight work ---------------------------
ee = ContinuousBatchingEngine(pipe, slots_per_device=1,
                              mesh=serving_mesh(8), quality_probe=0)
ee.warmup()
for r in reqs(8, steps=6, start=50):
    ee.submit(r, now=0.0)
ee.tick(now=0.0); ee.tick(now=0.0)          # all 8 slots 2 steps deep
flushed = ee.elastic_resize(n_devices=4)     # 4 keep running, 4 park
report['shrunk_slots'] = ee.slots
done = flushed + ee.run_until_idle(now=0.0)
report['resize_completed'] = sorted(r.request_id for r in done)
report['resize_expected'] = list(range(50, 58))
ee.elastic_resize(n_devices=8)               # devices rejoin
grown = serve(ee, reqs(8, steps=3, start=70))
report['grown_slots'] = ee.slots
report['grow_completed'] = len(grown)
snap = ee.metrics.snapshot()
report['resizes'] = snap.resizes
report['devices_after'] = snap.devices

# --- shed accounting: bounded queue on the mesh ------------------------
q = AdmissionQueue(max_depth=4, shed_policy='deadline-aware')
es = ContinuousBatchingEngine(pipe, slots_per_device=1,
                              mesh=serving_mesh(8), quality_probe=0,
                              queue=q)
es.warmup()
offered = 20
for r in reqs(offered, steps=4, start=200, slo_ms=60_000.0):
    es.submit(r, now=0.0)                    # 8 slots + 4 queued + 8 shed
completed = es.run_until_idle(now=0.0)
s = es.metrics.summary()
report['bounded'] = {'offered': offered, 'completed': len(completed),
                     'shed': int(s['shed'])}

# --- shed accounting: service-time-aware expiry ------------------------
q2 = AdmissionQueue()                        # unbounded, NOT deadline-aware
ex = ContinuousBatchingEngine(pipe, slots_per_device=1,
                              mesh=serving_mesh(8), quality_probe=0,
                              queue=q2)
ex.warmup()
offered2 = 12
for r in reqs(offered2, steps=4, start=300, slo_ms=10_000.0):
    ex.submit(r, now=0.0)                    # 8 active + 4 queued
ex.tick_s_estimate = 1e6                     # queued 4 can never finish
completed2 = ex.run_until_idle(now=0.0)
s2 = ex.metrics.summary()
report['expiry'] = {'offered': offered2, 'completed': len(completed2),
                    'shed': int(s2['shed']),
                    'by_reason': dict(ex.metrics.shed_by_reason)}
print('REPORT ' + json.dumps(report))
'''


@pytest.fixture(scope='module')
def mesh_report():
    env = dict(os.environ,
               XLA_FLAGS='--xla_force_host_platform_device_count=8',
               PYTHONPATH=SRC, JAX_PLATFORMS='cpu')
    out = subprocess.run([sys.executable, '-c', textwrap.dedent(_CHILD)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith('REPORT ')]
    assert line, out.stdout
    return json.loads(line[-1][len('REPORT '):])


def test_mesh_simulated(mesh_report):
    assert mesh_report['n_devices'] == 8
    assert mesh_report['slots'] == 8          # 1 slot/device


def test_sharded_matches_single_device(mesh_report):
    """Per-request parity with the single-device engine at the engine's
    equivalence tolerance, every request completed, and the slot buffer
    actually sharded over the data axis."""
    assert mesh_report['all_completed']
    assert mesh_report['x_sharded']
    assert mesh_report['max_abs_diff'] < 1e-5


def test_sharded_engine_deterministic(mesh_report):
    """Two identical sharded runs are BITWISE identical (the 1e-5 vs the
    single-device engine is cross-program rounding, not nondeterminism)."""
    assert mesh_report['deterministic']


def test_zero_recompiles_after_warmup_on_mesh(mesh_report):
    assert mesh_report['recompiles'] == {}


def test_decode_overlap_on_mesh(mesh_report):
    """Decode overlap defaults on for sharded engines and actually
    overlaps (finished requests' decodes materialize behind later
    ticks)."""
    assert mesh_report['overlap_default_on']
    assert mesh_report['overlapped_decodes'] > 0


def test_elastic_resize_completes_in_flight(mesh_report):
    """8 -> 4 mid-flight: the slot buffer shrinks to the per-device
    budget, displaced requests park and re-enter, every request
    completes; 4 -> 8 grows back."""
    assert mesh_report['shrunk_slots'] == 4
    assert mesh_report['resize_completed'] == mesh_report['resize_expected']
    assert mesh_report['grown_slots'] == 8
    assert mesh_report['grow_completed'] == 8
    assert mesh_report['resizes'] == 2
    assert mesh_report['devices_after'] == 8


def test_shed_accounting_reconciles_on_mesh(mesh_report):
    """No request is ever lost: completed + shed == offered, both for a
    bounded queue and for service-time-aware expiry (where the shed
    cause must be 'expired')."""
    b = mesh_report['bounded']
    assert b['completed'] + b['shed'] == b['offered']
    assert b['shed'] > 0
    e = mesh_report['expiry']
    assert e['completed'] + e['shed'] == e['offered']
    assert e['by_reason'].get('expired') == e['shed'] > 0


# --- host-side plan/helper logic (no mesh needed) -------------------------

def test_elastic_serving_plan():
    assert elastic_serving_plan(8, 2) == ((8,), ('data',), 16)
    assert elastic_serving_plan(3) == ((3,), ('data',), 3)
    with pytest.raises(ValueError):
        elastic_serving_plan(0)
    with pytest.raises(ValueError):
        elastic_serving_plan(4, 0)


def test_align_slots():
    assert align_slots(5, 4) == 8
    assert align_slots(8, 4) == 8
    assert align_slots(1, 1) == 1
    with pytest.raises(ValueError):
        align_slots(0, 4)
    with pytest.raises(ValueError):
        align_slots(4, 0)
