"""Diffusion substrate tests: schedules, samplers, UNet, pipeline, Table-I
parameter counts and W8A8 quality proxy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.diffusion import PAPER_MODELS, PAPER_PARAM_COUNTS
from repro.diffusion.samplers import ddim_sample, ddpm_sample, ddpm_step
from repro.diffusion.schedule import (cosine_schedule, ddpm_loss,
                                      linear_schedule, q_sample)
from repro.models.unet import UNetConfig, init_unet, unet_apply

TINY = UNetConfig('tiny', img_size=16, in_ch=3, base_ch=32, ch_mults=(1, 2),
                  n_res_blocks=1, attn_resolutions=(8,), n_heads=4,
                  timesteps=16)


@pytest.mark.smoke
def test_schedule_monotone():
    s = linear_schedule(100)
    ab = np.asarray(s.alpha_bars)
    assert np.all(np.diff(ab) < 0) and ab[0] < 1.0 and ab[-1] > 0.0
    c = cosine_schedule(100)
    assert np.all(np.asarray(c.betas) >= 0)


def test_forward_process_snr():
    """Eq. 1: signal-to-noise decays to ~0 at t=T-1."""
    s = linear_schedule(1000)
    x0 = jnp.ones((2, 4, 4, 1))
    noise = jax.random.normal(jax.random.PRNGKey(0), x0.shape)
    x_late = q_sample(s, x0, jnp.array([999, 999]), noise)
    # at t=T the sample is essentially pure noise
    corr = np.corrcoef(np.asarray(x_late).ravel(),
                       np.asarray(noise).ravel())[0, 1]
    assert corr > 0.98


@pytest.mark.parametrize('cfgname', list(PAPER_MODELS))
def test_table1_param_counts(cfgname):
    """UNet hyper-params reproduce Table I parameter counts to <0.5%."""
    cfg = PAPER_MODELS[cfgname]
    shapes = jax.eval_shape(lambda k: init_unet(k, cfg),
                            jax.random.PRNGKey(0))
    n = sum(int(np.prod(s.shape)) for s in
            jax.tree_util.tree_leaves(shapes))
    target = PAPER_PARAM_COUNTS[cfgname] * 1e6
    assert abs(n - target) / target < 0.005, (cfgname, n / 1e6)


def test_unet_shapes_and_finiteness():
    p = init_unet(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    eps = unet_apply(p, TINY, x, jnp.array([3, 9]))
    assert eps.shape == x.shape
    assert np.all(np.isfinite(np.asarray(eps)))


def test_unet_sparse_dataflow_equivalence():
    """C4 toggle changes the dataflow, not the function."""
    import dataclasses
    p = init_unet(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
    t = jnp.array([5])
    a = unet_apply(p, TINY, x, t)
    b = unet_apply(p, dataclasses.replace(TINY, sparse_dataflow=False),
                   x, t)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_ddpm_training_reduces_loss():
    sched = linear_schedule(TINY.timesteps)
    p = init_unet(jax.random.PRNGKey(0), TINY)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3)) * 0.5

    def apply_fn(params, x, t, ctx):
        return unet_apply(params, TINY, x, t, ctx)

    # the per-step loss is noisy (random t, random noise) — evaluate with a
    # FIXED key before/after training so the comparison is deterministic
    eval_key = jax.random.PRNGKey(123)

    @jax.jit
    def evaluate(params):
        return ddpm_loss(apply_fn, sched, params, x0, eval_key)

    @jax.jit
    def step(params, key):
        loss, g = jax.value_and_grad(
            lambda q: ddpm_loss(apply_fn, sched, q, x0, key))(params)
        params = jax.tree_util.tree_map(lambda a, b: a - 3e-3 * b,
                                        params, g)
        return params, loss
    before = float(evaluate(p))
    key = jax.random.PRNGKey(2)
    for i in range(25):
        key, k = jax.random.split(key)
        p, _ = step(p, k)
    after = float(evaluate(p))
    assert after < before, (before, after)


def test_samplers_produce_finite_images():
    sched = linear_schedule(TINY.timesteps)
    p = init_unet(jax.random.PRNGKey(0), TINY)

    def eps_fn(x, t):
        return unet_apply(p, TINY, x, t)
    img = jax.jit(lambda k: ddim_sample(sched, eps_fn, (2, 16, 16, 3), k,
                                        steps=4))(jax.random.PRNGKey(3))
    assert img.shape == (2, 16, 16, 3)
    assert np.all(np.isfinite(np.asarray(img)))


def test_ddpm_step_variance():
    """Eq. 2: at t=0 no noise is re-added (deterministic final step)."""
    sched = linear_schedule(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 1))
    eps_fn = lambda xx, tt: jnp.zeros_like(xx)
    a = ddpm_step(sched, eps_fn, x, 0, jax.random.PRNGKey(1))
    b = ddpm_step(sched, eps_fn, x, 0, jax.random.PRNGKey(2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_w8a8_unet_quality_proxy():
    """Table-I proxy: W8A8 UNet output stays close to fp32 (relative L2 on
    the predicted noise, the quantity that drives IS changes)."""
    p = init_unet(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    t = jnp.array([5, 11])
    a = unet_apply(p, TINY, x, t, quant=False)
    b = unet_apply(p, TINY, x, t, quant=True)
    rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
    assert rel < 0.10, rel


def test_deepcache_baseline():
    """DeepCache (the paper's algorithmic baseline [21]): refresh pass is
    bit-identical to the full UNet; skip steps reuse deep features with
    bounded drift and strictly fewer MACs."""
    import dataclasses
    from repro.diffusion.deepcache import (deepcache_workload_factor,
                                           unet_apply_cached)
    cfg = dataclasses.replace(TINY, ch_mults=(1, 2, 2))
    p = init_unet(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    t = jnp.array([5, 5])
    full = unet_apply(p, cfg, x, t)
    eps_r, cache = unet_apply_cached(p, cfg, x, t, None, refresh=True)
    np.testing.assert_allclose(np.asarray(eps_r), np.asarray(full), atol=0)
    x2 = x + 0.05 * jax.random.normal(jax.random.PRNGKey(2), x.shape)
    full2 = unet_apply(p, cfg, x2, jnp.array([4, 4]))
    eps_s, _ = unet_apply_cached(p, cfg, x2, jnp.array([4, 4]), cache,
                                 refresh=False)
    rel = float(jnp.linalg.norm(eps_s - full2) / jnp.linalg.norm(full2))
    assert rel < 0.2, rel
    f = deepcache_workload_factor(cfg, interval=5)
    assert 0.1 < f < 0.9


# ---------------------------------------------------------------------------
# precision-policy API (replaces the bare quant flag)
# ---------------------------------------------------------------------------

@pytest.mark.quant
def test_precision_policy_equals_deprecated_quant_flag():
    """policy=PrecisionPolicy.w8a8() and the deprecated quant=True build
    the SAME graph — bit-identical outputs — and the boolean spelling
    warns."""
    from repro.core.precision import PrecisionPolicy, resolve
    p = init_unet(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    t = jnp.array([5, 11])
    with pytest.warns(DeprecationWarning):
        old = unet_apply(p, TINY, x, t, quant=True)
    new = unet_apply(p, TINY, x, t, policy=PrecisionPolicy.w8a8())
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    with pytest.warns(DeprecationWarning):
        assert resolve(None, True) == PrecisionPolicy.w8a8()


@pytest.mark.quant
def test_precision_policy_validation_and_names():
    from repro.core.precision import (PRECISION_NAMES, PrecisionPolicy,
                                      resolve)
    assert set(PRECISION_NAMES) == {'fp32', 'w8a8', 'w8a8+noise'}
    for name in PRECISION_NAMES:
        pol = PrecisionPolicy.from_name(name)
        assert pol.name == name
        assert resolve(name) == pol              # str spelling resolves too
    with pytest.raises(ValueError):
        PrecisionPolicy.from_name('int4')
    with pytest.raises(ValueError):
        PrecisionPolicy(backend='fp8')
    with pytest.raises(ValueError):
        # noise model requires the quantized backend
        from repro.core.photonic.noise import NoiseModel
        PrecisionPolicy(backend='fp32', noise=NoiseModel())
    # frozen + hashable: usable as a jit closure / dict key
    assert hash(PrecisionPolicy.w8a8()) == hash(PrecisionPolicy.w8a8())


@pytest.mark.quant
def test_prequantize_calibration_matches_dynamic():
    """Serve-time calibration: prequantized weights agree with the
    dynamic w8a8 path to ~1 LSB (XLA constant-folds the in-graph weight
    quantization differently, flipping round-tie int8 values)."""
    from repro.core.precision import PrecisionPolicy
    from repro.core.quantization import QTensor
    from repro.diffusion.pipeline import DiffusionPipeline
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), TINY,
                                  policy=PrecisionPolicy.w8a8())
    pq = pipe.prequantize()
    assert pq.policy.calibration == 'prequant'
    n_q = sum(isinstance(l, QTensor) for l in
              jax.tree_util.tree_leaves(
                  pq.unet_params,
                  is_leaf=lambda l: isinstance(l, QTensor)))
    assert n_q > 0                       # attn projections became QTensors
    a = pipe.generate(jax.random.PRNGKey(3), batch=1, steps=3)
    b = pq.generate(jax.random.PRNGKey(3), batch=1, steps=3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@pytest.mark.quant
def test_noisy_policy_deterministic_in_pipeline():
    """w8a8+noise generation is reproducible under the policy's seed and
    differs across seeds."""
    from repro.core.precision import PrecisionPolicy
    from repro.diffusion.pipeline import DiffusionPipeline
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)
    p0 = PrecisionPolicy.w8a8_noise(noise_seed=0)
    p1 = PrecisionPolicy.w8a8_noise(noise_seed=1)
    a = pipe.generate(jax.random.PRNGKey(2), batch=1, steps=3, policy=p0)
    b = pipe.generate(jax.random.PRNGKey(2), batch=1, steps=3, policy=p0)
    c = pipe.generate(jax.random.PRNGKey(2), batch=1, steps=3, policy=p1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(jnp.max(jnp.abs(a - c))) > 0.0
    # and stays within the analog error envelope of the clean w8a8 path
    q = pipe.generate(jax.random.PRNGKey(2), batch=1, steps=3,
                      policy=PrecisionPolicy.w8a8())
    rel = float(jnp.linalg.norm(a - q) / jnp.linalg.norm(q))
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# cache-aware scheduling substrate (DeepCache parity, trajectory edges)
# ---------------------------------------------------------------------------

@pytest.mark.sched
def test_generate_deepcache_interval1_matches_generate():
    """With interval=1 every DeepCache step is a refresh, and refresh is
    bit-identical to the full UNet pass — so the whole trajectory must
    reproduce the plain DDIM pipeline."""
    from repro.diffusion.pipeline import DiffusionPipeline
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)
    key = jax.random.PRNGKey(5)
    a = pipe.generate(key, batch=2, steps=4)
    b = pipe.generate_deepcache(key, batch=2, steps=4, interval=1)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               atol=1e-5, rtol=0)
    # and a caching run with the same seed stays in the same ballpark
    c = pipe.generate_deepcache(key, batch=2, steps=4, interval=2)
    rel = float(jnp.linalg.norm(c - a) / jnp.linalg.norm(a))
    assert rel < 0.5, rel


@pytest.mark.sched
@pytest.mark.quant
def test_unet_apply_cached_under_w8a8_policy():
    """The cached fast path composes with the precision-policy API: a
    w8a8 refresh pass is bit-identical to the w8a8 full pass, and the
    skip pass stays within the quantization drift envelope."""
    import dataclasses
    from repro.core.precision import PrecisionPolicy
    from repro.diffusion.deepcache import unet_apply_cached
    cfg = dataclasses.replace(TINY, ch_mults=(1, 2, 2))
    p = init_unet(jax.random.PRNGKey(0), cfg)
    pol = PrecisionPolicy.w8a8()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    t = jnp.array([5, 5])
    full_q = unet_apply(p, cfg, x, t, policy=pol)
    eps_r, cache = unet_apply_cached(p, cfg, x, t, None, refresh=True,
                                     policy=pol)
    np.testing.assert_allclose(np.asarray(eps_r), np.asarray(full_q),
                               atol=0)
    x2 = x + 0.05 * jax.random.normal(jax.random.PRNGKey(2), x.shape)
    full2 = unet_apply(p, cfg, x2, jnp.array([4, 4]), policy=pol)
    eps_s, _ = unet_apply_cached(p, cfg, x2, jnp.array([4, 4]), cache,
                                 refresh=False, policy=pol)
    assert np.all(np.isfinite(np.asarray(eps_s)))
    rel = float(jnp.linalg.norm(eps_s - full2) / jnp.linalg.norm(full2))
    assert rel < 0.25, rel


@pytest.mark.sched
@pytest.mark.smoke
def test_ddim_timesteps_edges():
    """The single trajectory source every consumer reads: steps=1 jumps
    straight from T-1, steps=T visits every timestep, and interior
    counts are strictly decreasing T-1 ... 0 (no duplicate endpoints)."""
    from repro.diffusion.samplers import ddim_timesteps
    sched = linear_schedule(16)
    one = ddim_timesteps(sched, 1)
    assert one.dtype == np.int32 and one.tolist() == [15]
    full = ddim_timesteps(sched, 16)
    assert full.tolist() == list(range(15, -1, -1))
    for steps in (2, 3, 5, 7, 16):
        ts = ddim_timesteps(sched, steps)
        assert len(ts) == steps
        assert ts[0] == 15 and ts[-1] == 0
        assert np.all(np.diff(ts) < 0), ts
