"""Continuous-batching serving engine tests: numerical equivalence with
sequential per-request sampling, zero recompilation after warmup,
scheduler completeness under staggered arrivals, metric monotonicity,
queue priorities and the slot/bucket policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.diffusion.samplers import ddim_sample, ddim_step, ddim_timesteps
from repro.diffusion.schedule import linear_schedule
from repro.models.autoencoder import VAEConfig
from repro.models.unet import UNetConfig
from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                           GenerationRequest, PhotonicAccountant,
                           BucketRouter, bucket_for, choose_slots)

TINY = UNetConfig('tiny-serve', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


def _drive(engine, submits, max_ticks=200):
    """Logical-clock loop: ``submits`` maps tick index -> requests."""
    results, now = [], 0.0
    for k in range(max_ticks):
        for req in submits.get(k, ()):
            assert engine.submit(req, now=now)
        results.extend(engine.tick(now=now))
        now += 1.0
        if engine.busy:
            continue
        if all(t <= k for t in submits):
            return results
    raise AssertionError('engine did not drain')


# ---------------------------------------------------------------------------
# ddim_step refactor
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_ddim_step_vectorizes_per_sample_timesteps():
    """One mixed-timestep call == per-sample scalar-timestep calls."""
    sched = linear_schedule(32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 4, 4, 2))
    eps = jax.random.normal(jax.random.PRNGKey(1), x.shape)
    t = jnp.array([30, 17, 2], jnp.int32)
    t_prev = jnp.array([17, 2, -1], jnp.int32)
    mixed = ddim_step(sched, eps, x, t, t_prev)
    for b in range(3):
        one = ddim_step(sched, eps[b:b + 1], x[b:b + 1],
                        int(t[b]), int(t_prev[b]))
        np.testing.assert_allclose(np.asarray(mixed[b]),
                                   np.asarray(one[0]), atol=1e-6)


def test_ddim_sample_unchanged_by_refactor():
    """ddim_sample still denoises pure noise toward the data scale."""
    sched = linear_schedule(32)
    out = ddim_sample(sched, lambda x, t: jnp.zeros_like(x), (2, 4, 4, 1),
                      jax.random.PRNGKey(0), steps=8)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_mixed_timestep_equals_sequential_sampling(pipe):
    """Staggered requests with DIFFERENT step counts, multiplexed through
    shared mixed-timestep steps, must match per-request sequential DDIM
    (DiffusionPipeline.generate, batch=1) at atol 1e-5."""
    engine = ContinuousBatchingEngine(pipe, slots=3)
    reqs = [GenerationRequest(i, seed=100 + i, steps=s)
            for i, s in enumerate([3, 5, 4, 2])]
    # 4 requests into 3 slots, staggered over the first ticks
    results = _drive(engine, {0: reqs[:2], 1: [reqs[2]], 3: [reqs[3]]})
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3]
    for r in results:
        ref = pipe.generate(jax.random.PRNGKey(100 + r.request_id),
                            batch=1, steps=r.steps)
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=1e-5)


def test_engine_guided_slots_match_pipeline_guidance():
    """Per-slot classifier-free guidance: a guided and an unguided
    request sharing ticks each match their sequential counterpart, and
    the guided tick variant compiles exactly once at warmup."""
    cfg = UNetConfig('tiny-sdm', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=16, context_dim=8)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    ctx1 = jax.random.normal(jax.random.PRNGKey(9), (1, 4, 8))
    ctx = jnp.tile(ctx1, (2, 1, 1))                   # same text, 2 slots
    engine = ContinuousBatchingEngine(p, slots=2, context=ctx)
    engine.warmup()
    warm = engine.compile_stats()
    assert warm.get('_step_guided', 0) == 1
    reqs = [GenerationRequest(0, seed=11, steps=3, guidance=2.5),
            GenerationRequest(1, seed=12, steps=3)]
    results = _drive(engine, {0: reqs})
    assert engine.compile_stats() == warm
    for r in results:
        req = reqs[r.request_id]
        ref = p.generate(jax.random.PRNGKey(req.seed), batch=1,
                         steps=req.steps, context=ctx1,
                         guidance=req.guidance)
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=1e-5)


def test_engine_with_vae_matches_pipeline():
    vae = VAEConfig(img_size=16, in_ch=3, z_ch=4, base_ch=16,
                    ch_mults=(1, 2), groups=8)
    unet = UNetConfig('tiny-ldm', img_size=8, in_ch=4, base_ch=32,
                      ch_mults=(1, 2), n_res_blocks=1,
                      attn_resolutions=(4,), n_heads=4, timesteps=16,
                      latent=True)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), unet, vae_cfg=vae)
    engine = ContinuousBatchingEngine(p, slots=2)
    results = _drive(engine, {0: [GenerationRequest(0, seed=7, steps=3)]})
    ref = p.generate(jax.random.PRNGKey(7), batch=1, steps=3)
    assert results[0].image.shape == np.asarray(ref[0]).shape
    np.testing.assert_allclose(results[0].image, np.asarray(ref[0]),
                               atol=1e-5)


@pytest.mark.smoke
def test_zero_recompilation_after_warmup(pipe):
    """After warmup, serving any mix of steps/seeds/arrival patterns
    triggers no new XLA compilations (compile-count probe)."""
    engine = ContinuousBatchingEngine(pipe, slots=2)
    engine.warmup()
    warm = engine.compile_stats()
    assert all(v >= 1 for v in warm.values()), warm
    reqs = [GenerationRequest(i, seed=i, steps=s)
            for i, s in enumerate([2, 6, 3, 4, 5])]
    results = _drive(engine, {0: reqs[:3], 2: reqs[3:]})
    assert len(results) == 5
    assert engine.compile_stats() == warm


def test_scheduler_staggered_arrivals_all_complete_metrics_monotone(pipe):
    """More requests than slots, staggered arrivals: everything drains,
    and completed/tick/energy counters are monotone along the way."""
    engine = ContinuousBatchingEngine(pipe, slots=2)
    engine.warmup()
    reqs = [GenerationRequest(i, seed=50 + i, steps=2 + (i % 3),
                              slo_ms=1e9) for i in range(6)]
    seen, completed_series, energy_series = [], [], []
    now = 0.0
    for k in range(100):
        if k < len(reqs):
            engine.submit(reqs[k], now=now)
        seen.extend(engine.tick(now=now))
        snap = engine.metrics.snapshot(active_slots=engine.active_count,
                                      queued=len(engine.queue))
        completed_series.append(snap.completed)
        energy_series.append(snap.total_energy_j)
        now += 1.0
        if k >= len(reqs) and not engine.busy:
            break
    assert sorted(r.request_id for r in seen) == list(range(6))
    assert completed_series == sorted(completed_series)
    assert energy_series == sorted(energy_series)
    m = engine.metrics
    assert m.percentile_latency(50) <= m.percentile_latency(95)
    assert m.requests_per_s() > 0
    assert m.slo_violations == 0
    # latency bookkeeping: queue delay + service == end-to-end
    for r in seen:
        assert r.latency_s == pytest.approx(r.queue_delay_s + r.service_s)
        assert r.energy_j > 0 and r.epb_pj > 0


def test_photonic_energy_scales_with_steps(pipe):
    acct = PhotonicAccountant(TINY)
    e2, _ = acct.energy(2)
    e6, _ = acct.energy(6)
    assert e6 == pytest.approx(3 * e2, rel=1e-6)
    assert acct.energy(2, guided=True)[0] == pytest.approx(2 * e2, rel=1e-6)
    # engine results carry exactly the accountant's numbers
    engine = ContinuousBatchingEngine(pipe, slots=1, photonic=acct)
    res = _drive(engine, {0: [GenerationRequest(0, seed=1, steps=2)]})
    assert res[0].energy_j == pytest.approx(e2)


# ---------------------------------------------------------------------------
# queue / batcher policies
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_queue_priority_then_fifo_and_depth_bound():
    q = AdmissionQueue(max_depth=3)
    lo1 = GenerationRequest(1, seed=1, priority=0)
    lo2 = GenerationRequest(2, seed=2, priority=0)
    hi = GenerationRequest(3, seed=3, priority=5)
    assert q.submit(lo1, now=0.0) and q.submit(lo2, now=1.0)
    assert q.submit(hi, now=2.0)
    assert not q.submit(GenerationRequest(4, seed=4), now=3.0)  # full
    assert q.rejected == 1
    order = [q.pop().request.request_id for _ in range(3)]
    assert order == [3, 1, 2]            # priority first, FIFO within
    assert q.pop() is None
    assert q.oldest_wait(10.0) == 0.0


def test_choose_slots_littles_law():
    # 4 req/s x (10 steps x 50ms) = 2 in flight; /0.8 util -> 3 slots
    assert choose_slots(4.0, 0.05, 10) == 3
    assert choose_slots(0.0, 0.05, 10) == 1
    assert choose_slots(1e6, 0.05, 10, max_slots=16) == 16


def test_bucket_router_routes_and_ticks(pipe):
    router = BucketRouter()
    b = router.register(ContinuousBatchingEngine(pipe, slots=1))
    assert b == bucket_for(TINY)
    assert router.submit(GenerationRequest(0, seed=3, steps=2), now=0.0)
    out = []
    for k in range(20):
        out.extend(router.tick(now=float(k)))
        if not router.busy:
            break
    assert [r.request_id for r in out] == [0]
    with pytest.raises(ValueError):
        router.register(ContinuousBatchingEngine(pipe, slots=1))
