"""Continuous-batching serving engine tests: numerical equivalence with
sequential per-request sampling, zero recompilation after warmup,
scheduler completeness under staggered arrivals, metric monotonicity,
queue priorities and the slot/bucket policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.pipeline import DiffusionPipeline
from repro.diffusion.samplers import ddim_sample, ddim_step, ddim_timesteps
from repro.diffusion.schedule import linear_schedule
from repro.models.autoencoder import VAEConfig
from repro.models.unet import UNetConfig
from repro.core.precision import PrecisionPolicy
from repro.serving import (AdmissionQueue, ContinuousBatchingEngine,
                           GenerationRequest, PhotonicAccountant,
                           BucketRouter, bucket_for, choose_slots,
                           group_by_precision)

TINY = UNetConfig('tiny-serve', img_size=16, in_ch=3, base_ch=32,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                  n_heads=4, timesteps=16)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


def _drive(engine, submits, max_ticks=200):
    """Logical-clock loop: ``submits`` maps tick index -> requests."""
    results, now = [], 0.0
    for k in range(max_ticks):
        for req in submits.get(k, ()):
            assert engine.submit(req, now=now)
        results.extend(engine.tick(now=now))
        now += 1.0
        if engine.busy:
            continue
        if all(t <= k for t in submits):
            return results
    raise AssertionError('engine did not drain')


# ---------------------------------------------------------------------------
# ddim_step refactor
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_ddim_step_vectorizes_per_sample_timesteps():
    """One mixed-timestep call == per-sample scalar-timestep calls."""
    sched = linear_schedule(32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 4, 4, 2))
    eps = jax.random.normal(jax.random.PRNGKey(1), x.shape)
    t = jnp.array([30, 17, 2], jnp.int32)
    t_prev = jnp.array([17, 2, -1], jnp.int32)
    mixed = ddim_step(sched, eps, x, t, t_prev)
    for b in range(3):
        one = ddim_step(sched, eps[b:b + 1], x[b:b + 1],
                        int(t[b]), int(t_prev[b]))
        np.testing.assert_allclose(np.asarray(mixed[b]),
                                   np.asarray(one[0]), atol=1e-6)


def test_ddim_sample_unchanged_by_refactor():
    """ddim_sample still denoises pure noise toward the data scale."""
    sched = linear_schedule(32)
    out = ddim_sample(sched, lambda x, t: jnp.zeros_like(x), (2, 4, 4, 1),
                      jax.random.PRNGKey(0), steps=8)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_mixed_timestep_equals_sequential_sampling(pipe):
    """Staggered requests with DIFFERENT step counts, multiplexed through
    shared mixed-timestep steps, must match per-request sequential DDIM
    (DiffusionPipeline.generate, batch=1) at atol 1e-5."""
    engine = ContinuousBatchingEngine(pipe, slots=3)
    reqs = [GenerationRequest(i, seed=100 + i, steps=s)
            for i, s in enumerate([3, 5, 4, 2])]
    # 4 requests into 3 slots, staggered over the first ticks
    results = _drive(engine, {0: reqs[:2], 1: [reqs[2]], 3: [reqs[3]]})
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3]
    for r in results:
        ref = pipe.generate(jax.random.PRNGKey(100 + r.request_id),
                            batch=1, steps=r.steps)
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=1e-5)


def test_engine_guided_slots_match_pipeline_guidance():
    """Per-slot classifier-free guidance: a guided and an unguided
    request sharing ticks each match their sequential counterpart, and
    the guided tick variant compiles exactly once at warmup."""
    cfg = UNetConfig('tiny-sdm', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(8,),
                     n_heads=4, timesteps=16, context_dim=8)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    ctx1 = jax.random.normal(jax.random.PRNGKey(9), (1, 4, 8))
    ctx = jnp.tile(ctx1, (2, 1, 1))                   # same text, 2 slots
    engine = ContinuousBatchingEngine(p, slots=2, context=ctx)
    engine.warmup()
    warm = engine.compile_stats()
    assert warm.get('_step_guided', 0) == 1
    reqs = [GenerationRequest(0, seed=11, steps=3, guidance=2.5),
            GenerationRequest(1, seed=12, steps=3)]
    results = _drive(engine, {0: reqs})
    assert engine.compile_stats() == warm
    for r in results:
        req = reqs[r.request_id]
        ref = p.generate(jax.random.PRNGKey(req.seed), batch=1,
                         steps=req.steps, context=ctx1,
                         guidance=req.guidance)
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=1e-5)


def test_engine_with_vae_matches_pipeline():
    vae = VAEConfig(img_size=16, in_ch=3, z_ch=4, base_ch=16,
                    ch_mults=(1, 2), groups=8)
    unet = UNetConfig('tiny-ldm', img_size=8, in_ch=4, base_ch=32,
                      ch_mults=(1, 2), n_res_blocks=1,
                      attn_resolutions=(4,), n_heads=4, timesteps=16,
                      latent=True)
    p = DiffusionPipeline.init(jax.random.PRNGKey(0), unet, vae_cfg=vae)
    engine = ContinuousBatchingEngine(p, slots=2)
    results = _drive(engine, {0: [GenerationRequest(0, seed=7, steps=3)]})
    ref = p.generate(jax.random.PRNGKey(7), batch=1, steps=3)
    assert results[0].image.shape == np.asarray(ref[0]).shape
    np.testing.assert_allclose(results[0].image, np.asarray(ref[0]),
                               atol=1e-5)


@pytest.mark.smoke
def test_zero_recompilation_after_warmup(pipe):
    """After warmup, serving any mix of steps/seeds/arrival patterns
    triggers no new XLA compilations (compile-count probe)."""
    engine = ContinuousBatchingEngine(pipe, slots=2)
    engine.warmup()
    warm = engine.compile_stats()
    assert all(v >= 1 for v in warm.values()), warm
    reqs = [GenerationRequest(i, seed=i, steps=s)
            for i, s in enumerate([2, 6, 3, 4, 5])]
    results = _drive(engine, {0: reqs[:3], 2: reqs[3:]})
    assert len(results) == 5
    assert engine.compile_stats() == warm


def test_scheduler_staggered_arrivals_all_complete_metrics_monotone(pipe):
    """More requests than slots, staggered arrivals: everything drains,
    and completed/tick/energy counters are monotone along the way."""
    engine = ContinuousBatchingEngine(pipe, slots=2)
    engine.warmup()
    reqs = [GenerationRequest(i, seed=50 + i, steps=2 + (i % 3),
                              slo_ms=1e9) for i in range(6)]
    seen, completed_series, energy_series = [], [], []
    now = 0.0
    for k in range(100):
        if k < len(reqs):
            engine.submit(reqs[k], now=now)
        seen.extend(engine.tick(now=now))
        snap = engine.metrics.snapshot(active_slots=engine.active_count,
                                      queued=len(engine.queue))
        completed_series.append(snap.completed)
        energy_series.append(snap.total_energy_j)
        now += 1.0
        if k >= len(reqs) and not engine.busy:
            break
    assert sorted(r.request_id for r in seen) == list(range(6))
    assert completed_series == sorted(completed_series)
    assert energy_series == sorted(energy_series)
    m = engine.metrics
    assert m.percentile_latency(50) <= m.percentile_latency(95)
    assert m.requests_per_s() > 0
    assert m.slo_violations == 0
    # latency bookkeeping: queue delay + service == end-to-end
    for r in seen:
        assert r.latency_s == pytest.approx(r.queue_delay_s + r.service_s)
        assert r.energy_j > 0 and r.epb_pj > 0


def test_photonic_energy_scales_with_steps(pipe):
    acct = PhotonicAccountant(TINY)
    e2, _ = acct.energy(2)
    e6, _ = acct.energy(6)
    assert e6 == pytest.approx(3 * e2, rel=1e-6)
    assert acct.energy(2, guided=True)[0] == pytest.approx(2 * e2, rel=1e-6)
    # engine results carry exactly the accountant's numbers — an fp32
    # request is billed the GPU digital baseline, not the photonic path
    e2_fp32, _ = acct.energy(2, precision='fp32')
    engine = ContinuousBatchingEngine(pipe, slots=1, photonic=acct)
    res = _drive(engine, {0: [GenerationRequest(0, seed=1, steps=2)]})
    assert res[0].energy_j == pytest.approx(e2_fp32)
    # quantized request on the same engine: the DiffLight number
    engine2 = ContinuousBatchingEngine(pipe, slots=1, photonic=acct,
                                       quality_probe=0)
    res2 = _drive(engine2, {0: [GenerationRequest(1, seed=1, steps=2,
                                                  precision='w8a8')]})
    assert res2[0].energy_j == pytest.approx(e2)
    assert res2[0].energy_j < res[0].energy_j / 100


# ---------------------------------------------------------------------------
# queue / batcher policies
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_queue_priority_then_fifo_and_depth_bound():
    q = AdmissionQueue(max_depth=3)
    lo1 = GenerationRequest(1, seed=1, priority=0)
    lo2 = GenerationRequest(2, seed=2, priority=0)
    hi = GenerationRequest(3, seed=3, priority=5)
    assert q.submit(lo1, now=0.0) and q.submit(lo2, now=1.0)
    assert q.submit(hi, now=2.0)
    assert not q.submit(GenerationRequest(4, seed=4), now=3.0)  # full
    assert q.rejected == 1
    order = [q.pop().request.request_id for _ in range(3)]
    assert order == [3, 1, 2]            # priority first, FIFO within
    assert q.pop() is None
    assert q.oldest_wait(10.0) == 0.0


def test_choose_slots_littles_law():
    # 4 req/s x (10 steps x 50ms) = 2 in flight; /0.8 util -> 3 slots
    assert choose_slots(4.0, 0.05, 10) == 3
    assert choose_slots(0.0, 0.05, 10) == 1
    assert choose_slots(1e6, 0.05, 10, max_slots=16) == 16


def test_bucket_router_routes_and_ticks(pipe):
    router = BucketRouter()
    b = router.register(ContinuousBatchingEngine(pipe, slots=1))
    assert b == bucket_for(TINY)
    assert router.submit(GenerationRequest(0, seed=3, steps=2), now=0.0)
    out = []
    for k in range(20):
        out.extend(router.tick(now=float(k)))
        if not router.busy:
            break
    assert [r.request_id for r in out] == [0]
    with pytest.raises(ValueError):
        router.register(ContinuousBatchingEngine(pipe, slots=1))


# ---------------------------------------------------------------------------
# precision policies: the quantized photonic fast path
# ---------------------------------------------------------------------------

@pytest.mark.quant
@pytest.mark.smoke
def test_w8a8_engine_matches_standalone_quant_pipeline(pipe):
    """A w8a8 request through the engine matches the standalone
    quant=True DDIM pipeline (the deprecated boolean spelling) for the
    same seed/steps.  Per-row activation scales keep batch elements
    independent, so the math is identical; the tolerance is ~1 LSB of
    the 8-bit datapath (atol 1e-3), because XLA fuses the row-scale
    reduction differently for the engine's slot-batch shape than for
    batch-1, and a ~1e-7 float difference in x/scale can flip one int8
    rounding at a tie boundary."""
    with pytest.warns(DeprecationWarning):
        qpipe = DiffusionPipeline.init(jax.random.PRNGKey(0), TINY,
                                       quant=True)
    engine = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    reqs = [GenerationRequest(i, seed=40 + i, steps=s, precision='w8a8')
            for i, s in enumerate([3, 5, 2])]
    results = _drive(engine, {0: reqs[:2], 2: [reqs[2]]})
    assert sorted(r.request_id for r in results) == [0, 1, 2]
    for r in results:
        ref = qpipe.generate(jax.random.PRNGKey(40 + r.request_id),
                             batch=1, steps=r.steps)
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=1e-3)
        # and it ran the quant path, not fp32: strictly closer to the
        # quantized reference than to the fp32 one
        fp = pipe.generate(jax.random.PRNGKey(40 + r.request_id),
                           batch=1, steps=r.steps)
        d_quant = float(np.max(np.abs(r.image - np.asarray(ref[0]))))
        d_fp32 = float(np.max(np.abs(r.image - np.asarray(fp[0]))))
        assert d_quant < d_fp32
        assert r.precision == 'w8a8' and r.policy.quantized


@pytest.mark.quant
def test_mixed_precision_ticks_zero_recompiles(pipe):
    """One engine serving fp32 + w8a8 + w8a8+noise side by side: per-tick
    precision grouping keeps every step call on a pre-compiled function —
    compile stats are frozen after one warmup per policy."""
    engine = ContinuousBatchingEngine(pipe, slots=3, quality_probe=0)
    engine.warmup(precisions=('fp32', 'w8a8', 'w8a8+noise'))
    warm = engine.compile_stats()
    assert warm['_step'] == 1
    assert warm['_step[w8a8]'] == 1
    assert warm['_step[w8a8+noise]'] == 1
    mix = ['fp32', 'w8a8', 'w8a8+noise']
    reqs = [GenerationRequest(i, seed=60 + i, steps=2 + (i % 3),
                              precision=mix[i % 3]) for i in range(6)]
    results = _drive(engine, {0: reqs[:4], 2: reqs[4:]})
    assert sorted(r.request_id for r in results) == list(range(6))
    assert engine.compile_stats() == warm
    # each request still matches its own standalone trajectory (fp32 at
    # float precision; w8a8 to ~1 LSB — see the equivalence test above)
    for r in results:
        if r.precision == 'w8a8+noise':
            continue
        ref = pipe.generate(jax.random.PRNGKey(60 + r.request_id), batch=1,
                            steps=r.steps,
                            policy=PrecisionPolicy.from_name(r.precision))
        atol = 1e-5 if r.precision == 'fp32' else 1e-3
        np.testing.assert_allclose(r.image, np.asarray(ref[0]), atol=atol)


@pytest.mark.quant
def test_frontier_reports_accuracy_vs_epb(pipe):
    """snapshot().frontier: quantized requests sit ~2 orders of magnitude
    below fp32 in EPB and carry a PSNR/MSE quality probe vs the fp32
    reference; fp32 requests ARE the reference (no probe)."""
    engine = ContinuousBatchingEngine(pipe, slots=2)
    engine.warmup(precisions=('fp32', 'w8a8'))
    reqs = [GenerationRequest(0, seed=5, steps=3, precision='fp32'),
            GenerationRequest(1, seed=5, steps=3, precision='w8a8')]
    results = _drive(engine, {0: reqs})
    by_id = {r.request_id: r for r in results}
    assert by_id[0].quality_mse is None
    assert by_id[1].quality_mse is not None and by_id[1].quality_mse >= 0
    assert by_id[1].quality_psnr_db > 20          # tracks fp32 closely
    snap = engine.metrics.snapshot()
    f = snap.frontier
    assert set(f) == {'fp32', 'w8a8'}
    assert f['w8a8']['mean_epb_pj'] < f['fp32']['mean_epb_pj'] / 50
    assert f['w8a8']['probed'] == 1
    assert np.isnan(f['fp32']['mean_psnr_db'])
    # per-request frontier points mirror the results
    pts = {p.request_id: p for p in engine.metrics.frontier_points}
    assert pts[1].psnr_db == by_id[1].quality_psnr_db
    assert pts[0].epb_pj == by_id[0].epb_pj


@pytest.mark.quant
def test_noisy_engine_deterministic_under_seed(pipe):
    """w8a8+noise serving is reproducible: identical engines and request
    sequences produce bit-identical images; a different noise seed does
    not."""
    def run(noise_seed):
        e = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0,
                                     noise_seed=noise_seed)
        reqs = [GenerationRequest(i, seed=70 + i, steps=3,
                                  precision='w8a8+noise') for i in range(2)]
        return {r.request_id: r.image for r in _drive(e, {0: reqs})}

    a, b, c = run(0), run(0), run(1)
    for i in a:
        np.testing.assert_array_equal(a[i], b[i])
    assert any(np.any(a[i] != c[i]) for i in a)


@pytest.mark.quant
@pytest.mark.smoke
def test_request_precision_validation():
    with pytest.raises(ValueError, match='precision'):
        GenerationRequest(0, seed=1, precision='int4')
    with pytest.raises(ValueError, match='precision'):
        GenerationRequest(0, seed=1, precision='W8A8')   # case-sensitive
    assert GenerationRequest(0, seed=1,
                             precision='w8a8+noise').precision == 'w8a8+noise'


def test_group_by_precision_masks():
    groups = group_by_precision(['fp32', None, 'w8a8', 'fp32', None])
    assert set(groups) == {'fp32', 'w8a8'}
    np.testing.assert_array_equal(groups['fp32'],
                                  [True, False, False, True, False])
    np.testing.assert_array_equal(groups['w8a8'],
                                  [False, False, True, False, False])
    assert group_by_precision([None, None]) == {}


def test_choose_slots_per_precision_mapping():
    # per-precision load terms add across one shared slot buffer:
    # fp32 1 req/s x 10 x 0.1s = 1.0; w8a8 4 req/s x 10 x 0.025s = 1.0
    n = choose_slots({'fp32': 1.0, 'w8a8': 4.0},
                     {'fp32': 0.1, 'w8a8': 0.025}, 10)
    assert n == 3                                 # ceil(2.0 / 0.8)
    # scalar step time broadcast over the mapping
    assert choose_slots({'fp32': 2.0, 'w8a8': 2.0}, 0.05, 10) == 3
    assert choose_slots({'fp32': 0.0}, 0.05, 10) == 1
