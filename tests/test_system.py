"""End-to-end behaviour tests for the paper's system:
W8A8 diffusion serving (the DiffLight workload), a small dry-run through the
real dryrun machinery, and the roofline bookkeeping."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), '..', 'src')


def test_diffusion_serving_end_to_end():
    """Batched request serving: noise -> W8A8 UNet denoise -> image."""
    from repro.diffusion.pipeline import DiffusionPipeline
    from repro.models.unet import UNetConfig
    cfg = UNetConfig('tiny', img_size=16, in_ch=3, base_ch=32,
                     ch_mults=(1, 2), n_res_blocks=1,
                     attn_resolutions=(8,), n_heads=4, timesteps=16)
    pipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg, quant=True)
    img = jax.jit(lambda k: pipe.generate(k, batch=2, steps=3))(
        jax.random.PRNGKey(1))
    assert img.shape == (2, 16, 16, 3)
    assert np.all(np.isfinite(np.asarray(img)))


def test_dryrun_machinery_small_scale():
    """The real run_cell path (lower+compile+probe+roofline) on an
    8-virtual-device mesh with a reduced config."""
    code = textwrap.dedent('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import repro.configs.base as B
        B.SHAPES['train_4k'] = dataclasses.replace(
            B.SHAPES['train_4k'], seq_len=64, global_batch=8)
        import repro.launch.dryrun as DR
        from repro.launch.mesh import make_mesh
        from repro.configs.registry import smoke_config
        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        r = DR.run_cell('internlm2-1.8b', 'train_4k', multi_pod=True,
                        mesh=mesh, cfg=smoke_config('internlm2-1.8b'))
        assert r['memory']['peak_bytes_per_device'] > 0
        assert r['cost']['flops_per_device'] > 0
        assert r['roofline']['dominant'] in ('compute_s', 'memory_s',
                                             'collective_s')
        assert r['cost']['steps_full'] == 2
        print('DRYRUN-OK', r['roofline']['dominant'])
    ''')
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS='cpu')
    out = subprocess.run([sys.executable, '-c', code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert 'DRYRUN-OK' in out.stdout


@pytest.mark.smoke
def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = '''
      %ag = bf16[16,128]{1,0} all-gather(bf16[1,128]{1,0} %p), replica_groups={}
      %ar.1 = f32[64]{0} all-reduce(f32[64]{0} %x), to_apply=%add
      %tup = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %a, f32[8]{0} %b)
    '''
    r = parse_collectives(hlo)
    assert r['count_per_kind'] == {'all-gather': 1, 'all-reduce': 1,
                                   'all-to-all': 1}
    assert r['bytes_per_kind']['all-gather'] == 16 * 128 * 2
    assert r['bytes_per_kind']['all-reduce'] == 64 * 4
    assert r['bytes_per_kind']['all-to-all'] == 64
    # all-reduce weighted 2x
    assert r['weighted_bytes'] == 16 * 128 * 2 + 2 * 64 * 4 + 64


@pytest.mark.smoke
def test_roofline_terms_math():
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    assert PEAK_FLOPS_BF16 == 197e12
    assert HBM_BW == 819e9
    assert ICI_BW == 50e9
