"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# W8A8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('M,K,N', [
    (8, 64, 32), (64, 200, 96), (128, 128, 128), (1, 300, 7),
    (257, 129, 65), (16, 1024, 256),
])
def test_w8a8_matches_oracle(M, K, N):
    x = _arr((M, K))
    w = _arr((K, N))
    out_i = ops.w8a8_matmul(x, w, mode='interpret')
    out_x = ops.w8a8_matmul(x, w, mode='xla')
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_x),
                               rtol=0, atol=0)  # bit-identical int path


@pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
def test_w8a8_close_to_fp(dtype):
    x = _arr((32, 256), dtype)
    w = _arr((256, 64), dtype)
    out = ops.w8a8_matmul(x, w, mode='interpret')
    exact = x.astype(jnp.float32) @ w.astype(jnp.float32)
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.03, rel     # 8-bit error budget (paper Table I regime)


def test_w8a8_batched_leading_dims():
    x = _arr((2, 3, 96))
    w = _arr((96, 48))
    out = ops.w8a8_matmul(x, w, mode='interpret')
    assert out.shape == (2, 3, 48)


# ---------------------------------------------------------------------------
# Flash attention (streaming LSE softmax)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('S,T,d,causal', [
    (128, 128, 64, False), (128, 128, 64, True),
    (256, 256, 32, True), (128, 384, 64, False),
    (100, 128, 64, True),       # ragged q
])
def test_flash_attention_vs_ref(S, T, d, causal):
    B, H = 2, 3
    q = _arr((B, H, S, d))
    k = _arr((B, H, T, d))
    v = _arr((B, H, T, d))
    if causal and S != T:
        k, v = k[:, :, :S], v[:, :, :S]
        T = S
    out = ops.flash_attention(q, k, v, causal=causal, mode='interpret')
    exp = ref.attention_ref(q.reshape(B * H, S, d), k.reshape(B * H, T, d),
                            v.reshape(B * H, T, d), causal=causal)
    np.testing.assert_allclose(np.asarray(out).reshape(B * H, S, d),
                               np.asarray(exp), atol=2e-5)


def test_flash_equals_streaming_ref():
    """Kernel == the executable rendering of paper Eq. 4 streaming."""
    from repro.core.lse_softmax import streaming_attention_ref
    q = _arr((2, 2, 128, 32))
    k = _arr((2, 2, 256, 32))
    v = _arr((2, 2, 256, 32))
    a = ops.flash_attention(q, k, v, mode='interpret')
    b = streaming_attention_ref(q, k, v, block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# Fused GroupNorm + swish
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('N,H,W,C,g', [
    (2, 8, 8, 64, 8), (1, 16, 16, 32, 32), (3, 4, 4, 96, 6),
])
def test_fused_gn_swish(N, H, W, C, g):
    x = _arr((N, H, W, C))
    sc = _arr((C,))
    bi = _arr((C,))
    out = ops.fused_gn_swish(x, sc, bi, groups=g, mode='interpret')
    exp = ref.gn_swish_ref(x, sc, bi, groups=g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_fused_gn_swish_matches_layer_composition():
    from repro.models import layers as L
    x = _arr((2, 8, 8, 32))
    p = L.init_groupnorm(32)
    fused = ops.fused_gn_swish(x, p['scale'], p['bias'], groups=8,
                               mode='interpret')
    composed = L.swish(L.groupnorm(p, x, groups=8))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# analog-noise injection (the engine's w8a8+noise policy)
# ---------------------------------------------------------------------------

@pytest.mark.quant
def test_noisy_w8a8_deterministic_under_key():
    """noisy_w8a8_matmul is a pure function of its key: the same key
    reproduces the same analog draw (the serving engine relies on this
    for reproducible w8a8+noise requests), different keys differ, and
    the whole thing compiles (trace-time crosstalk constant)."""
    from repro.core.photonic.noise import NoiseModel, noisy_w8a8_matmul
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    k1, k2 = jax.random.PRNGKey(7), jax.random.PRNGKey(8)
    a = noisy_w8a8_matmul(k1, x, w)
    b = noisy_w8a8_matmul(k1, x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = noisy_w8a8_matmul(k2, x, w)
    assert float(jnp.max(jnp.abs(a - c))) > 0.0
    # jit-compiled call agrees with the eager one
    j = jax.jit(lambda k, xx, ww: noisy_w8a8_matmul(k, xx, ww))(k1, x, w)
    np.testing.assert_allclose(np.asarray(j), np.asarray(a), atol=1e-5)


@pytest.mark.quant
def test_noisy_w8a8_collapses_to_plain_w8a8_at_zero_noise():
    """With all noise sigmas ~0 and crosstalk off, the noisy matmul is
    the plain W8A8 matmul."""
    from repro.core.photonic.noise import NoiseModel, noisy_w8a8_matmul
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    quiet = NoiseModel(sigma_w_lsb=0.0, sigma_x_lsb=0.0, sigma_pd_lsb=0.0,
                       crosstalk_db_per_channel=-1000.0)
    y = noisy_w8a8_matmul(jax.random.PRNGKey(0), x, w, model=quiet)
    ref_q = ops.w8a8_matmul(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_q), atol=1e-5)
