"""Beyond-paper extension tests: analog-noise robustness model and
gradient accumulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.photonic.noise import (NoiseModel, crosstalk_sigma_lsb,
                                       noisy_w8a8_matmul, robustness_sweep)


def test_crosstalk_monotone_in_channels():
    m = NoiseModel()
    sig = [crosstalk_sigma_lsb(n, m) for n in (2, 8, 16, 36, 64)]
    assert all(a <= b for a, b in zip(sig, sig[1:]))
    assert crosstalk_sigma_lsb(1, m) == 0.0


def test_noise_sweep_reproduces_wdm_design_point():
    """At the paper's 36-channel limit the analog error stays within the
    8-bit quantization floor (~3%); beyond it, it keeps growing."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    sweep = robustness_sweep(jax.random.PRNGKey(2), x, w)
    assert sweep[36] < 0.03
    assert sweep[64] > sweep[36] > sweep[2]


def test_noisy_matmul_zero_noise_matches_w8a8():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    silent = NoiseModel(sigma_w_lsb=0.0, sigma_x_lsb=0.0, sigma_pd_lsb=0.0,
                        crosstalk_db_per_channel=-300.0)
    a = noisy_w8a8_matmul(jax.random.PRNGKey(2), x, w, model=silent)
    b = ops.w8a8_matmul(x, w, mode='xla')
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_grad_accumulation_matches_full_batch():
    from repro.configs.registry import smoke_config
    from repro.launch.steps import build_train_step, init_params
    from repro.optim.accumulation import build_accum_train_step
    from repro.optim.adamw import AdamWConfig, init_adamw
    cfg = smoke_config('internlm2-1.8b')
    p = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(p)
    oc = AdamWConfig(warmup_steps=1, total_steps=10)
    batch = {
        'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab),
        'labels': jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                     cfg.vocab)}
    p1, _, m1 = jax.jit(build_train_step(cfg, oc, dtype=jnp.float32))(
        p, opt, batch)
    p2, _, m2 = jax.jit(build_accum_train_step(cfg, oc, 2,
                                               dtype=jnp.float32))(
        p, opt, batch)
    assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-5
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2))]
    assert max(diffs) < 1e-4, max(diffs)
