"""Cache- and convergence-aware scheduler: DeepCache-phased slots plus
speculative early-exit draining (``repro.serving.engine``)."""
import jax
import numpy as np
import pytest

from repro.models.unet import UNetConfig
from repro.diffusion.pipeline import DiffusionPipeline
from repro.serving import (ContinuousBatchingEngine, GenerationRequest,
                           AdmissionQueue, PhotonicAccountant,
                           split_cache_phase)

TINY = UNetConfig('tiny-cache-serve', img_size=8, in_ch=1, base_ch=8,
                  ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(4,),
                  n_heads=2, timesteps=32, groups=4)


@pytest.fixture(scope='module')
def pipe():
    return DiffusionPipeline.init(jax.random.PRNGKey(0), TINY)


def _req(i, steps=7, **kw):
    return GenerationRequest(request_id=i, seed=100 + i, steps=steps, **kw)


@pytest.mark.sched
@pytest.mark.smoke
def test_cached_engine_zero_recompiles_and_phase(pipe):
    """Warmup pre-compiles exactly the (refresh, skip) step pair; a full
    serve touches nothing else, every skip tick is whole-batch (phase
    alignment), and per-request eval counts follow the cadence."""
    eng = ContinuousBatchingEngine(pipe, slots=4, cache_interval=3,
                                   quality_probe=0)
    eng.warmup()
    warm = eng.compile_stats()
    assert warm['_step_refresh'] == 1
    assert warm['_step_skip'] == 1
    for i in range(5):
        eng.submit(_req(i, steps=7), now=0.0)
    results = eng.run_until_idle(now=0.0)
    assert len(results) == 5
    assert eng.compile_stats() == warm, 'recompiled mid-serve'
    for r in results:
        # interval 3, admitted at phase 0: refresh at ticks 0, 3, 6
        assert r.full_evals == 3
        assert r.cached_evals == 4
        assert r.steps_executed == 7
        assert not r.early_exit
        assert np.all(np.isfinite(r.image))
    snap = eng.metrics.snapshot()
    assert snap.mixed_ticks == 0          # every tick whole-batch
    assert snap.cached_steps == 5 * 4
    assert snap.full_steps == 5 * 3
    assert 0.5 < snap.cache_hit_rate < 0.6   # 20 / 35


@pytest.mark.sched
@pytest.mark.smoke
def test_opt_out_matches_plain_engine(pipe):
    """A request that opts out (cache_interval=1) rides the refresh path
    every tick — its output must match the plain full-step engine."""
    eng_plain = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    eng_cache = ContinuousBatchingEngine(pipe, slots=2, cache_interval=3,
                                         quality_probe=0)
    for eng in (eng_plain, eng_cache):
        eng.warmup()
    out = {}
    for name, eng in (('plain', eng_plain), ('cache', eng_cache)):
        eng.submit(_req(0, steps=5, cache_interval=1), now=0.0)
        out[name] = eng.run_until_idle(now=0.0)[0]
    assert out['cache'].cached_evals == 0
    assert out['cache'].full_evals == 5
    np.testing.assert_allclose(out['cache'].image, out['plain'].image,
                               atol=1e-5, rtol=0)
    # opted-out slots produce mixed ticks when cached slots coexist;
    # alone they don't
    assert eng_cache.metrics.snapshot().mixed_ticks == 0


@pytest.mark.sched
def test_phase_aligned_admission_mid_flight(pipe):
    """A request arriving mid-cadence is held until the next refresh tick
    so the shared cadence never fragments (mixed_ticks stays 0)."""
    eng = ContinuousBatchingEngine(pipe, slots=4, cache_interval=3,
                                   quality_probe=0)
    eng.warmup()
    eng.submit(_req(0, steps=7), now=0.0)
    done = []
    done += eng.tick(now=0.0)      # phase 0 -> 1
    done += eng.tick(now=0.0)      # phase 1 -> 2: mid-cadence
    eng.submit(_req(1, steps=7), now=0.0)
    done += eng.tick(now=0.0)      # phase 2: admission held
    assert sum(a is not None for a in eng._slot) == 1
    done += eng.tick(now=0.0)      # phase 0: admitted on the refresh tick
    assert sum(a is not None for a in eng._slot) == 2
    while eng.busy:
        done += eng.tick(now=0.0)
    assert len(done) == 2
    assert eng.metrics.snapshot().mixed_ticks == 0
    for r in done:
        assert r.full_evals == 3 and r.cached_evals == 4


@pytest.mark.sched
@pytest.mark.smoke
def test_early_exit_drains_and_saves_steps(pipe):
    """With a huge tolerance every request converges immediately: it
    drains after exit_min_steps with the converged x0 committed, the
    steps-saved histogram fills, and the energy bill shrinks."""
    eng = ContinuousBatchingEngine(pipe, slots=2, exit_tol=1e9,
                                   exit_patience=1, quality_probe=0)
    eng.warmup()
    eng.submit(_req(0, steps=12), now=0.0)
    r = eng.run_until_idle(now=0.0)[0]
    assert r.early_exit
    assert r.steps_executed == eng.exit_min_steps
    assert r.steps_saved == 12 - eng.exit_min_steps
    snap = eng.metrics.snapshot()
    assert snap.early_exits == 1
    assert snap.steps_saved == r.steps_saved
    assert snap.steps_saved_hist.get(r.steps_saved) == 1
    # full-run comparison: same request, exit disabled
    eng2 = ContinuousBatchingEngine(pipe, slots=2, quality_probe=0)
    eng2.warmup()
    eng2.submit(_req(0, steps=12), now=0.0)
    r2 = eng2.run_until_idle(now=0.0)[0]
    assert not r2.early_exit and r2.steps_executed == 12
    assert r.energy_j < r2.energy_j


@pytest.mark.sched
@pytest.mark.smoke
def test_exit_tol_zero_disables_early_exit(pipe):
    eng = ContinuousBatchingEngine(pipe, slots=1, exit_tol=1e9,
                                   exit_patience=1, quality_probe=0)
    eng.warmup()
    eng.submit(_req(0, steps=6, exit_tol=0.0), now=0.0)  # per-request off
    r = eng.run_until_idle(now=0.0)[0]
    assert not r.early_exit and r.steps_executed == 6


@pytest.mark.sched
@pytest.mark.smoke
def test_skip_ticks_billed_shallow():
    """Skip ticks are billed through the DeepCache workload transform:
    cheaper than full ticks, dearer than free."""
    acct = PhotonicAccountant(TINY)
    assert 0.0 < acct.shallow_fraction < 1.0
    full, _ = acct.energy(5, precision='w8a8')
    mixed, _ = acct.energy_evals(1, 4, precision='w8a8')
    floor, _ = acct.energy_evals(1, 0, precision='w8a8')
    assert floor < mixed < full
    # no skips -> identical to the step-count bill (same simulate point)
    e_steps = acct.energy(3, precision='fp32')
    e_evals = acct.energy_evals(3, 0, precision='fp32')
    assert e_steps == e_evals


@pytest.mark.sched
@pytest.mark.smoke
def test_shed_surfaced_in_metrics(pipe):
    """A bounded admission queue sheds overload; the shed count surfaces
    in the metrics snapshot and summary."""
    eng = ContinuousBatchingEngine(pipe, slots=1,
                                   queue=AdmissionQueue(max_depth=2),
                                   quality_probe=0)
    accepted = [eng.submit(_req(i, steps=2), now=0.0) for i in range(5)]
    assert accepted == [True, True, False, False, False]
    assert eng.metrics.snapshot().shed == 3
    assert eng.metrics.summary()['shed'] == 3
    eng.warmup()
    assert len(eng.run_until_idle(now=0.0)) == 2


@pytest.mark.sched
def test_guided_and_quantized_cached_paths(pipe):
    """Caching composes with guidance (two cache buffers) and with the
    w8a8 precision policy (per-policy refresh/skip pairs), still with
    zero recompiles after warmup."""
    ctx = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 16))
    cfg = UNetConfig('tiny-cache-guided', img_size=8, in_ch=1, base_ch=8,
                     ch_mults=(1, 2), n_res_blocks=1, attn_resolutions=(4,),
                     n_heads=2, timesteps=32, groups=4, context_dim=16)
    gpipe = DiffusionPipeline.init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(gpipe, slots=2, context=ctx,
                                   cache_interval=2, quality_probe=0)
    eng.warmup(precisions=('fp32', 'w8a8'))
    warm = eng.compile_stats()
    for label in ('_step_refresh', '_step_skip', '_step_refresh_guided',
                  '_step_skip_guided', '_step_refresh[w8a8]',
                  '_step_skip[w8a8]'):
        assert warm[label] == 1, label
    eng.submit(_req(0, steps=5, guidance=2.0), now=0.0)
    eng.submit(_req(1, steps=5, precision='w8a8'), now=0.0)
    results = eng.run_until_idle(now=0.0)
    assert len(results) == 2
    assert eng.compile_stats() == warm
    for r in results:
        assert r.cached_evals > 0
        assert np.all(np.isfinite(r.image))


@pytest.mark.sched
@pytest.mark.smoke
def test_split_cache_phase():
    mask = np.array([True, True, False, True])
    refresh = np.array([True, False, True, False])
    r, s = split_cache_phase(mask, refresh)
    assert r.tolist() == [True, False, False, False]
    assert s.tolist() == [False, True, False, True]
    assert not np.any(r & s)
    assert ((r | s) == mask).all()


@pytest.mark.sched
def test_frontier_reports_scheduler_columns(pipe):
    """The per-policy frontier carries the quality-vs-throughput axes:
    executed vs requested steps, cache hit rate and early exits."""
    eng = ContinuousBatchingEngine(pipe, slots=2, cache_interval=3,
                                   exit_tol=1e9, exit_patience=1,
                                   quality_probe=1)
    eng.warmup()
    eng.submit(_req(0, steps=6), now=0.0)
    r = eng.run_until_idle(now=0.0)[0]
    f = eng.metrics.frontier()['fp32']
    assert f['mean_steps_requested'] == 6.0
    assert f['mean_steps_executed'] == float(r.steps_executed)
    assert f['mean_steps_saved'] == float(r.steps_saved)
    assert f['early_exits'] == 1
    # the cached/early-exited fp32 request is probe-eligible
    assert r.quality_mse is not None
