"""Property & unit tests for the paper's core techniques (C1-C6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip('hypothesis', exc_type=ImportError)
st = pytest.importorskip('hypothesis.strategies', exc_type=ImportError)
from hypothesis import given, settings

from repro.core import attention_decomp as AD
from repro.core import sparse_dataflow as SD
from repro.core.lse_softmax import (lse_softmax, stream_finalize,
                                    stream_init, stream_update,
                                    streaming_attention_ref)
from repro.core.quantization import (QTensor, fake_quantize, quantize,
                                     quantize_per_channel,
                                     quantization_error, w8a8_matmul_ref)

hypothesis.settings.register_profile(
    'ci', deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile('ci')


# ---------------------------------------------------------------------------
# C1: W8A8 quantization
# ---------------------------------------------------------------------------

@given(st.integers(2, 40), st.integers(2, 40), st.floats(0.1, 100.0))
def test_quant_roundtrip_bounded(m, n, scale):
    """Round-trip error bounded by scale/2 per element (symmetric int8)."""
    rng = np.random.default_rng(m * 41 + n)
    x = jnp.asarray(rng.normal(size=(m, n)) * scale, jnp.float32)
    q = quantize(x)
    err = np.abs(np.asarray(q.dequantize() - x))
    bound = float(np.max(np.abs(np.asarray(x)))) / 127.0 * 0.5 + 1e-6
    assert err.max() <= bound * 1.01


def test_quant_preserves_zero_and_sign():
    x = jnp.array([[-3.0, 0.0, 5.0]])
    d = np.asarray(quantize(x).dequantize())
    assert d[0, 1] == 0.0
    assert d[0, 0] < 0 < d[0, 2]


@given(st.integers(4, 64))
def test_per_channel_better_or_equal(n):
    rng = np.random.default_rng(n)
    # heterogeneous channel scales: per-channel must win
    w = rng.normal(size=(32, n)) * (10.0 ** rng.uniform(-2, 2, size=(1, n)))
    w = jnp.asarray(w, jnp.float32)
    e_tensor = float(quantization_error(w))
    e_chan = float(jnp.linalg.norm(
        quantize_per_channel(w).dequantize() - w) / jnp.linalg.norm(w))
    assert e_chan <= e_tensor * 1.001


def test_w8a8_matmul_ref_error_budget():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)
    out = w8a8_matmul_ref(x, quantize_per_channel(w))
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02


# ---------------------------------------------------------------------------
# C2: LSE softmax decomposition + streaming
# ---------------------------------------------------------------------------

@given(st.integers(2, 100), st.floats(-50, 50))
def test_lse_softmax_equals_jax(n, shift):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(3, n)) * 5 + shift, jnp.float32)
    np.testing.assert_allclose(np.asarray(lse_softmax(x)),
                               np.asarray(jax.nn.softmax(x, -1)),
                               atol=1e-6)


def test_lse_softmax_extreme_values_stable():
    x = jnp.array([[1e4, -1e4, 0.0], [-1e30, -1e30, -1e30]], jnp.float32)
    p = np.asarray(lse_softmax(x))
    assert np.all(np.isfinite(p))
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)


@given(st.integers(1, 8), st.integers(8, 96))
def test_streaming_equals_monolithic(blocks, d):
    """Paper's pipelined softmax == one-shot softmax attention, any block
    split (the correctness core of the flash kernel)."""
    rng = np.random.default_rng(blocks * 100 + d)
    T = blocks * 16
    q = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, T, d)), jnp.float32)
    out = streaming_attention_ref(q, k, v, block=16)
    s = jnp.einsum('bsd,btd->bst', q, k) * d ** -0.5
    exp = jnp.einsum('bst,btd->bsd', jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_stream_update_permutation_invariant():
    """Streaming state is invariant to KV block order (non-causal)."""
    rng = np.random.default_rng(7)
    scores = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    values = jnp.asarray(rng.normal(size=(4, 32, 8)), jnp.float32)
    def run(order):
        st_ = stream_init((4,), 8)
        for i in order:
            st_ = stream_update(st_, scores[:, i * 8:(i + 1) * 8],
                                values[:, i * 8:(i + 1) * 8])
        return np.asarray(stream_finalize(st_))
    np.testing.assert_allclose(run([0, 1, 2, 3]), run([3, 1, 0, 2]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# C3: attention matmul decomposition
# ---------------------------------------------------------------------------

@given(st.integers(2, 16), st.integers(2, 32), st.integers(4, 32),
       st.integers(4, 32))
def test_decomposition_equivalence(S, T, d, dk):
    rng = np.random.default_rng(S + T + d + dk)
    q = jnp.asarray(rng.normal(size=(S, dk)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, dk)), jnp.float32)
    a = AD.scores_standard(q, x, w)
    b = AD.scores_reordered(q, x, w)
    c = AD.scores_auto(q, x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-3)


def test_decomp_flops_decode_regime():
    """Eq. 6 wins exactly where the paper deploys it (short Q, long KV with
    small d_k)... and the chooser picks it."""
    std, reo = AD.decomp_flops(S=1, T=4096, d=512, d_k=64)
    assert reo < std


# ---------------------------------------------------------------------------
# C4: sparse transposed-conv dataflow
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('H,W,Cin,Cout,k,s', [
    (8, 8, 3, 5, 4, 2), (7, 9, 2, 4, 3, 2), (6, 6, 3, 3, 5, 2),
    (5, 5, 2, 2, 4, 4), (4, 4, 1, 1, 6, 3), (8, 8, 2, 3, 3, 1),
])
def test_sparse_convt_exact(H, W, Cin, Cout, k, s):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, H, W, Cin)), jnp.float32)
    ker = jnp.asarray(rng.normal(size=(k, k, Cin, Cout)), jnp.float32)
    dense = SD.conv_transpose_dense(x, ker, s)
    sparse = SD.conv_transpose_sparse(x, ker, s)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=1e-4)


@given(st.integers(2, 6), st.integers(2, 4))
def test_zero_mac_fraction(k_over_s, s):
    k = k_over_s * s
    frac = SD.zero_mac_fraction(k, k, s)
    assert abs(frac - (1 - 1 / s ** 2)) < 1e-9


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    from repro.distributed.compression import (compress_with_feedback,
                                               decompress, init_residual)
    rng = np.random.default_rng(1)
    g = {'a': jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    res = init_residual(g)
    # accumulated reconstruction approaches accumulated gradient
    acc_true = jnp.zeros((64,))
    acc_rec = jnp.zeros((64,))
    for _ in range(50):
        c, res = compress_with_feedback(g, res)
        acc_rec = acc_rec + decompress(c)['a']
        acc_true = acc_true + g['a']
    rel = float(jnp.linalg.norm(acc_rec - acc_true) /
                jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


# ---------------------------------------------------------------------------
# serve-time weight pre-quantization (C1 at scale)
# ---------------------------------------------------------------------------

def test_quantize_params_structure_and_accuracy():
    import jax
    from repro.core.quantization import QTensor, quantize_params
    from repro.models import layers as L
    p = {'wq': L.init_linear(jax.random.PRNGKey(0), 128, 64, bias=True),
         'norm': L.init_rmsnorm(128)}
    pq = quantize_params(p, min_size=16)
    assert isinstance(pq['wq']['w'], QTensor)
    assert pq['wq']['b'].dtype == jnp.float32          # bias untouched
    assert pq['norm']['scale'].dtype == jnp.float32    # norm untouched
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 128)),
                    jnp.float32)
    a = L.linear(p['wq'], x)
    b = L.linear(pq['wq'], x)
    rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
    assert rel < 0.03, rel
