#!/usr/bin/env bash
# CI entrypoint (no make needed): tier-1 on CPU with `hypothesis` ABSENT.
#
# The ci_stubs shim shadows `hypothesis` so a missing optional package can
# never again abort collection of the whole suite — that failure class is
# caught here before merge.  Stages:
#   1. collection must succeed without hypothesis
#   2. smoke lane (-m smoke): fast signal first
#   3. quant serving lane (-m quant): the precision-policy fast path
#   4. sched lane (-m "sched and smoke"): the cache-/convergence-aware
#      scheduler's fast checks (DeepCache-phased slots, early exit)
#   5. full tier-1 suite
#
# CI_SMOKE_ONLY=1 stops after stage 2 (pre-push hook scale).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="$PWD/scripts/ci_stubs:$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu

echo '== [1/5] collection (hypothesis absent) =='
python -m pytest -q --collect-only >/dev/null

echo '== [2/5] smoke lane =='
python -m pytest -q -m smoke

if [ "${CI_SMOKE_ONLY:-0}" = "1" ]; then
    echo 'CI_SMOKE_ONLY=1: skipping full suite'
    exit 0
fi

echo '== [3/5] quant serving lane =='
python -m pytest -q -m quant

echo '== [4/5] sched lane =='
python -m pytest -q -m "sched and smoke"

echo '== [5/5] full tier-1 =='
python -m pytest -q
