#!/usr/bin/env bash
# CI entrypoint (no make needed): tier-1 on CPU with `hypothesis` ABSENT.
#
# The ci_stubs shim shadows `hypothesis` so a missing optional package can
# never again abort collection of the whole suite — that failure class is
# caught here before merge.  Stages:
#   1. collection must succeed without hypothesis
#   2. smoke lane (-m smoke): fast signal first
#   3. quant serving lane (-m quant): the precision-policy fast path
#   4. sched lane (-m "sched and smoke"): the cache-/convergence-aware
#      scheduler's fast checks (DeepCache-phased slots, early exit)
#   5. hardening lane (-m "overload or coldstart"): bounded-queue
#      shedding, deadline expiry, persistent compilation cache, restart
#   6. dist serving lane (-m dist_serving): the slot-sharded engine on
#      an 8-device simulated mesh (parity, elastic resize, overlap)
#   7. obs lane (-m obs): tracer semantics, exporters (strict JSON),
#      Prometheus exposition, trace <-> metrics reconciliation
#   8. full tier-1 suite
#   9. bench regression gate: serving/engine_rps must stay within
#      BENCH_TOL (default 10%) of the newest committed BENCH_PR*.json
#
# CI_SMOKE_ONLY=1 stops after stage 2 (pre-push hook scale).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="$PWD/scripts/ci_stubs:$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu

echo '== [1/9] collection (hypothesis absent) =='
python -m pytest -q --collect-only >/dev/null

echo '== [2/9] smoke lane =='
python -m pytest -q -m smoke

if [ "${CI_SMOKE_ONLY:-0}" = "1" ]; then
    echo 'CI_SMOKE_ONLY=1: skipping full suite'
    exit 0
fi

echo '== [3/9] quant serving lane =='
python -m pytest -q -m quant

echo '== [4/9] sched lane =='
python -m pytest -q -m "sched and smoke"

echo '== [5/9] hardening lane (overload + coldstart) =='
python -m pytest -q -m "overload or coldstart"

echo '== [6/9] dist serving lane (8-device simulated mesh) =='
python -m pytest -q -m dist_serving

echo '== [7/9] obs lane (tracing, exporters, exposition) =='
python -m pytest -q -m obs

echo '== [8/9] full tier-1 =='
python -m pytest -q

echo '== [9/9] bench regression gate =='
python benchmarks/run.py serving --check
