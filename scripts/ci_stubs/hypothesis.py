"""CI shim: makes `import hypothesis` fail even when the package is
installed, so the suite is exercised the way a hypothesis-less
environment sees it (collection must survive — tests that need it must
pytest.importorskip).  Prepended to PYTHONPATH by scripts/ci.sh."""
raise ImportError('hypothesis is disabled in the CI smoke lane '
                  '(scripts/ci_stubs); use pytest.importorskip')
